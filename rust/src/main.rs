//! `oea-serve` launcher.
//!
//! Subcommands:
//!   serve     start the HTTP serving frontend
//!   generate  one-shot generation from the command line
//!   ce-eval   teacher-forced CE comparison of a policy vs vanilla
//!   info      print backend / config info
//!
//! Backends (`--backend`):
//!   cpu   (default) hermetic pure-Rust reference backend with structured
//!         synthetic weights — runs anywhere `cargo` does, no artifacts
//!   pjrt  PJRT/XLA over AOT HLO artifacts; requires a build with
//!         `--features pjrt` and `make artifacts`
//!
//! Examples:
//!   oea-serve serve --config small --policy oea:k0=3 --max-running 16 \
//!       --port 8080
//!   oea-serve generate --config small --policy oea:k0=3 \
//!       --prompt "The quiet river" --max-tokens 32
//!   oea-serve ce-eval --config small --policy pruned:k0=3 --batch 16

use std::path::PathBuf;
use std::process::ExitCode;

use oea_serve::backend::cpu::kernels::{KernelMode, PanelDtype};
use oea_serve::backend::cpu::{CpuBackend, CpuOptions};
use oea_serve::backend::Backend;
use oea_serve::residency::{EvictPolicy, ResidencyConfig};
use oea_serve::config::ModelConfig;
use oea_serve::coordinator::{
    ControllerConfig, Engine, EngineConfig, GenRequest, Priority, SchedMode,
};
use oea_serve::eval;
use oea_serve::latency::H100Presets;
use oea_serve::model::ModelRunner;
use oea_serve::moe::policy::{Policy, PolicySpec};
use oea_serve::server;
use oea_serve::util::bpe::Tokenizer;
use oea_serve::util::cli::{Args, Spec};
use oea_serve::util::corpus::Corpus;
use oea_serve::util::error::Result;
use oea_serve::util::rng::Rng;

fn spec() -> Spec {
    Spec {
        name: "oea-serve",
        about: "MoE serving with Opportunistic Expert Activation (OEA) routing",
        options: vec![
            ("backend", true, "execution backend: cpu (default, hermetic) | pjrt \
                              (needs --features pjrt and artifacts)"),
            ("config", true, "model config: tiny | small | base | smoke (default small)"),
            ("artifacts", true, "artifact root (default ./artifacts; optional for cpu)"),
            ("data", true, "corpus dir (default ./data; optional for cpu)"),
            ("weight-seed", true, "cpu: synthetic-weight seed (default 0)"),
            ("policy", true, "routing policy, e.g. vanilla, pruned:k0=3, oea:k0=3, \
                              oea-full:k0=3,p=0.7,kmax=9,maxp=32, lynx:t=16, dynskip:tau=0.3, \
                              cache-aware:k0=4,alpha=0.5, ep:k0=4,ranks=4,topup=1"),
            ("ep-ranks", true, "cpu: expert-parallel rank shards executing the MoE stage \
                              (default: the policy's ranks, or 1). Must match an ep: \
                              policy's ranks when both are given"),
            ("kernels", true, "cpu: kernel implementation: scalar (default, the bitwise \
                              oracle) | simd (runtime-detected AVX2+FMA; falls back to \
                              scalar where unavailable)"),
            ("panel-dtype", true, "cpu: packed expert panel precision: f32 (default) | \
                              bf16 | int8 (per-row scales; fused dequant in the \
                              micro-kernel). Grouped dispatch only; shrinks residency \
                              page-in bytes and the cost model prices that"),
            ("expert-cache", true, "cpu: expert residency capacity (experts per layer); \
                              misses page packed panels in lazily (default: off, all \
                              experts pre-packed)"),
            ("evict", true, "cpu: residency eviction policy: lru | lfu | score \
                              (default lru; requires --expert-cache)"),
            ("prefetch", true, "cpu: residency lookahead page-ins per layer-step, fed by \
                              the previous step's router scores (default 0; requires \
                              --expert-cache)"),
            ("max-running", true, "max concurrent requests (default 8)"),
            ("sched", true, "scheduler: continuous (default; chunked prefill + per-step \
                              batch recomposition) | lockstep (whole-prompt prefill at \
                              admission — the fixed-batch oracle)"),
            ("prefill-chunk", true, "continuous: prompt tokens prefilled per slot per \
                              step (default: the model config's prefill_chunk)"),
            ("adaptive", false, "batch-adaptive routing: relax k0/alpha toward vanilla \
                              quality when the live decode batch empties (identity at \
                              a full batch)"),
            ("max-queue", true, "serve: waiting-request bound before 429 backpressure \
                              (default 64)"),
            ("http-workers", true, "serve: connection worker threads (default \
                              max-running + 16; a streaming handler occupies a worker \
                              for its whole generation)"),
            ("port", true, "serve: TCP port (default 8080)"),
            ("max-requests", true, "serve: drain and exit after N generations \
                              (default: run until POST /shutdown)"),
            ("trace", false, "serve: arm the flight recorder — span/event timelines \
                              for every request, served as Chrome trace JSON on \
                              GET /trace (load in Perfetto / chrome://tracing)"),
            ("trace-out", true, "serve: write the Chrome trace JSON to FILE after the \
                              graceful drain (implies --trace)"),
            ("no-mask-padding", false, "disable the padding-token routing fix (paper §6)"),
            ("faults", true, "cpu: deterministic fault-injection plan, e.g. \
                              'pagein-fail:rate=0.05,seed=7;rank-stall:rank=2,\
                              after_steps=50,us=20000;expert-poison:layer=3,expert=11' \
                              (requires grouped dispatch; empty plan = no hooks)"),
            ("step-budget-us", true, "watchdog: decode steps slower than this budget \
                              count as wedged in /metrics health (default: off)"),
            ("slo-ttft-ms", true, "SLO controller: p99 TTFT budget in ms; breaches \
                              tighten routing toward the configured policy, headroom \
                              relaxes it toward vanilla quality (default: off)"),
            ("slo-tpot-ms", true, "SLO controller: p99 TPOT budget in ms (default: off; \
                              either --slo-* budget arms the controller)"),
            ("slo-interval-steps", true, "SLO controller: decode steps between \
                              evaluations (default 32)"),
            ("slo-window", true, "SLO controller: tail window in samples for the \
                              windowed p99 (default 256)"),
            ("slo-min-samples", true, "SLO controller: samples a signal needs before it \
                              participates in a decision (default 16)"),
            ("slo-step", true, "SLO controller: tightness shift per decision in [0,1] \
                              (default 0.25)"),
            ("slo-headroom", true, "SLO controller: relax only when every armed tail is \
                              under this fraction of its budget (default 0.7)"),
            ("prompt", true, "generate: prompt text"),
            ("max-tokens", true, "generate: tokens to generate (default 32)"),
            ("temperature", true, "sampling temperature (default 0)"),
            ("top-p", true, "nucleus threshold (default 1.0)"),
            ("batch", true, "ce-eval: batch size (default 16)"),
            ("positions", true, "ce-eval: decode positions (default 48)"),
            ("mixed", false, "ce-eval: mixed-domain batches (default: domain-pure)"),
            ("seed", true, "rng seed (default 0)"),
        ],
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.iter().any(|a| a == "--help" || a == "-h") || argv.is_empty() {
        print!("{}", spec().usage());
        println!("\nsubcommands: serve | generate | ce-eval | info");
        return ExitCode::SUCCESS;
    }
    match run(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = spec().parse(argv, true)?;
    match args.str_or("backend", "cpu").as_str() {
        "cpu" => run_cpu(&args),
        "pjrt" => run_pjrt(&args),
        other => Err(oea_serve::Error::Config(format!(
            "unknown backend {other:?} (cpu | pjrt)"
        ))),
    }
}

// ---- shared, backend-generic command bodies ------------------------------

fn parse_policy(args: &Args, c: &ModelConfig) -> Result<Policy> {
    PolicySpec::parse(&args.str_or("policy", "vanilla"))?.build(c.top_k, c.n_experts)
}

fn f64_opt(args: &Args, name: &str) -> Result<Option<f64>> {
    match args.str_opt(name) {
        None => Ok(None),
        Some(s) => s.parse::<f64>().map(Some).map_err(|_| {
            oea_serve::Error::Config(format!("--{name} {s:?} is not a number"))
        }),
    }
}

/// `--slo-*` flags -> controller tuning; `None` unless at least one
/// latency budget is set (an unarmed controller is never installed, so
/// flagless runs stay bitwise identical to pre-controller builds).
fn controller_config(args: &Args) -> Result<Option<ControllerConfig>> {
    let mut cc = ControllerConfig::new();
    cc.slo_ttft_ms = f64_opt(args, "slo-ttft-ms")?;
    cc.slo_tpot_ms = f64_opt(args, "slo-tpot-ms")?;
    if !cc.is_armed() {
        return Ok(None);
    }
    if let Some(v) = args.usize_opt("slo-interval-steps")? {
        cc.interval_steps = v as u32;
    }
    if let Some(v) = args.usize_opt("slo-window")? {
        cc.window = v;
    }
    if let Some(v) = args.usize_opt("slo-min-samples")? {
        cc.min_samples = v;
    }
    if let Some(v) = f64_opt(args, "slo-step")? {
        cc.step = v;
    }
    if let Some(v) = f64_opt(args, "slo-headroom")? {
        cc.headroom = v;
    }
    Ok(Some(cc))
}

/// `--trace` / `--trace-out` -> one shared flight recorder for the
/// engine, the backend, and the `/trace` endpoint. `None` keeps the
/// tracing hot paths compiled out of the run entirely (the disabled
/// path is bitwise-identical to a build without tracing).
fn tracer_from_args(args: &Args) -> Option<std::sync::Arc<oea_serve::obs::Tracer>> {
    if args.flag("trace") || args.str_opt("trace-out").is_some() {
        Some(std::sync::Arc::new(oea_serve::obs::Tracer::new()))
    } else {
        None
    }
}

fn engine_config(args: &Args, c: &ModelConfig) -> Result<EngineConfig> {
    Ok(EngineConfig {
        mask_padding: !args.flag("no-mask-padding"),
        max_running: args.usize_or("max-running", 8)?,
        max_queue: args.usize_or("max-queue", 64)?,
        sched: SchedMode::from_cli(&args.str_or("sched", "continuous"))?,
        prefill_chunk: args.usize_opt("prefill-chunk")?,
        adaptive: args.flag("adaptive"),
        step_budget_us: args.usize_opt("step-budget-us")?.map(|v| v as u64),
        controller: controller_config(args)?,
        ..EngineConfig::new(parse_policy(args, c)?, H100Presets::for_config(&c.name))
    })
}

/// Quantized expert panels shrink the bytes a residency miss moves, so a
/// CPU run prices the cost model's `page_in_us` at the packed dtype's
/// actual panel size (bf16 halves it; int8 panels + per-row f32 scales
/// land near 0.26x of f32).
fn scale_page_in(ecfg: &mut EngineConfig, dtype: PanelDtype) {
    ecfg.cost_model.page_in_us *= match dtype {
        PanelDtype::F32 => 1.0,
        PanelDtype::Bf16 => 0.5,
        PanelDtype::Int8 => 0.26,
    };
}

/// CPU path only: the trained vocab when artifacts exist, byte-level
/// fallback otherwise (every model vocab here is >= 259, so byte-level
/// ids always fit). The PJRT path loads the manifest's vocab strictly —
/// a trained model with the wrong tokenizer must be a hard error.
fn cpu_tokenizer(args: &Args, cfg_name: &str) -> Tokenizer {
    let root = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let path = root.join(cfg_name).join("vocab.json");
    match Tokenizer::load(&path) {
        Ok(t) => t,
        Err(_) => {
            eprintln!("note: no trained vocab at {path:?}; using byte-level tokenizer");
            Tokenizer::byte_level()
        }
    }
}

fn cmd_generate<B: Backend>(
    args: &Args,
    runner: ModelRunner<B>,
    tok: Tokenizer,
    ecfg: EngineConfig,
) -> Result<()> {
    let prompt_text = args.str_or("prompt", "The quiet river carried the");
    let prompt: Vec<i32> = tok.encode(&prompt_text).iter().map(|&t| t as i32).collect();
    let mut engine = Engine::new(runner, ecfg)?;
    engine
        .submit(GenRequest {
            id: 1,
            prompt,
            max_new_tokens: args.usize_or("max-tokens", 32)?,
            temperature: args.f64_or("temperature", 0.0)? as f32,
            top_p: args.f64_or("top-p", 1.0)? as f32,
            seed: args.usize_or("seed", 0)? as u64,
            policy: None,
            deadline_ms: None,
            priority: Priority::default(),
        })
        .map_err(|e| oea_serve::Error::Config(format!("submit: {e}")))?;
    let done = engine.run_to_completion()?;
    for f in done {
        let text = tok.decode(&f.tokens.iter().map(|&t| t as u32).collect::<Vec<_>>());
        println!("--- request {} ({:?}, {} tokens)", f.id, f.reason, f.tokens.len());
        println!("{prompt_text}{text}");
    }
    println!(
        "\navg active experts: {:.1}  simulated MoE latency: {:.1} us  \
         measured MoE latency: {:.1} us",
        engine.moe.avg_t(),
        engine.moe.avg_latency_us(true),
        engine.moe.avg_latency_us(false),
    );
    Ok(())
}

fn cmd_ce_eval<B: Backend>(args: &Args, runner: ModelRunner<B>, tok: Tokenizer) -> Result<()> {
    let policy = parse_policy(args, runner.cfg())?;
    let mut rng = Rng::new(args.usize_or("seed", 0)? as u64);
    let b = args.usize_or("batch", 16)?;
    let positions = args.usize_or("positions", 48)?;
    let mixed = args.flag("mixed");
    // corpus-fed sequences when the data dir exists, hermetic synthetic
    // domain bands otherwise
    let seqs = match Corpus::load(&PathBuf::from(args.str_or("data", "data"))) {
        Ok(corpus) => {
            eval::sequences_from_corpus(&corpus, &tok, &mut rng, b, positions, mixed)
        }
        Err(_) => eval::synthetic_sequences(runner.cfg(), &mut rng, b, positions, mixed),
    };

    let k = runner.cfg().top_k;
    let vanilla = eval::forced_run(&runner, &seqs, positions, Policy::Vanilla { k }, true)?;
    let run = eval::forced_run(&runner, &seqs, positions, policy, true)?;
    let r = eval::ce_compare(&seqs, &run, &vanilla);
    println!(
        "policy={} B={b} positions={positions}\n  ce={:.4} ce_delta={:+.4} kl={:.5}\n  \
         avg_active_experts={:.2} (vanilla {:.2})  avg_moe_us_measured={:.1}",
        policy.label(),
        r.ce,
        r.ce_delta,
        r.kl_vanilla,
        r.avg_t,
        vanilla.avg_t,
        r.avg_moe_us,
    );
    Ok(())
}

fn cmd_info<B: Backend>(runner: ModelRunner<B>) -> Result<()> {
    println!("backend: {}", runner.backend.label());
    println!("config: {:#?}", runner.cfg());
    Ok(())
}

fn serve_preamble(
    args: &Args,
    c: &ModelConfig,
    backend: &str,
) -> Result<(String, server::ServeOptions)> {
    // validate the policy spec up front so typos fail before any engine
    // thread spawns
    let policy = parse_policy(args, c)?;
    let port = args.usize_or("port", 8080)?;
    // a generation handler holds its worker until the stream completes,
    // so the default pool must exceed max_running or the decode bucket
    // can never fill
    let max_running = args.usize_or("max-running", 8)?;
    let opts = server::ServeOptions {
        max_requests: args.usize_opt("max-requests")?,
        http_workers: args.usize_or("http-workers", max_running + 16)?,
        ready: None,
    };
    println!(
        "serving backend={backend} config={} policy={} sched={} \
         max_running={max_running} max_queue={} workers={} on 127.0.0.1:{port}",
        c.name,
        policy.label(),
        SchedMode::from_cli(&args.str_or("sched", "continuous"))?.label(),
        args.usize_or("max-queue", 64)?,
        opts.http_workers,
    );
    if let Some(plan) = args.str_opt("faults") {
        println!("fault plan armed: {plan}");
    }
    Ok((format!("127.0.0.1:{port}"), opts))
}

// ---- CPU backend (default, hermetic) -------------------------------------

fn cpu_runner(args: &Args) -> Result<ModelRunner<CpuBackend>> {
    let cfg = ModelConfig::preset(&args.str_or("config", "small"))?;
    let seed = args.usize_or("weight-seed", 0)? as u64;
    let mut opts = CpuOptions::from_env();
    // EP sharding: --ep-ranks, defaulting to the policy's ranks so
    // `--policy ep:ranks=4` alone shards the backend to match. A mismatch
    // between the two is a loud error — executed sharding and routed
    // sharding disagreeing would corrupt every per-rank number.
    let pol_ranks = parse_policy(args, &cfg)?.ranks();
    opts.ep_ranks = match args.usize_opt("ep-ranks")? {
        Some(r) => {
            if r == 0 || r > cfg.n_experts {
                return Err(oea_serve::Error::Config(format!(
                    "--ep-ranks {r} must be in 1..={} (n_experts)",
                    cfg.n_experts
                )));
            }
            if pol_ranks > 1 && r != pol_ranks {
                return Err(oea_serve::Error::Config(format!(
                    "--ep-ranks {r} conflicts with the policy's ranks={pol_ranks}"
                )));
            }
            r
        }
        None => pol_ranks,
    };
    match args.usize_opt("expert-cache")? {
        Some(capacity) => {
            if capacity == 0 {
                return Err(oea_serve::Error::Config(
                    "--expert-cache must be >= 1 (omit the flag to disable residency)".into(),
                ));
            }
            let evict = EvictPolicy::from_cli(&args.str_or("evict", "lru"))?;
            let prefetch = args.usize_or("prefetch", 0)?;
            opts.residency = Some(ResidencyConfig::new(capacity, evict, prefetch));
        }
        None => {
            // loud failure over silently ignoring cache knobs
            for dep in ["evict", "prefetch"] {
                if args.str_opt(dep).is_some() {
                    return Err(oea_serve::Error::Config(format!(
                        "--{dep} requires --expert-cache"
                    )));
                }
            }
        }
    }
    if let Some(v) = args.str_opt("kernels") {
        opts.kernels = match v.as_str() {
            "scalar" => KernelMode::Scalar,
            "simd" => KernelMode::Simd,
            other => {
                return Err(oea_serve::Error::Config(format!(
                    "--kernels {other:?} (scalar | simd)"
                )))
            }
        };
    }
    if let Some(v) = args.str_opt("panel-dtype") {
        opts.panel_dtype = match v.as_str() {
            "f32" => PanelDtype::F32,
            "bf16" => PanelDtype::Bf16,
            "int8" => PanelDtype::Int8,
            other => {
                return Err(oea_serve::Error::Config(format!(
                    "--panel-dtype {other:?} (f32 | bf16 | int8)"
                )))
            }
        };
    }
    let mut backend = CpuBackend::synthetic_with(cfg, seed, opts);
    if let Some(spec) = args.str_opt("faults") {
        if backend.dispatch_mode() != oea_serve::backend::cpu::DispatchMode::Grouped {
            return Err(oea_serve::Error::Config(
                "--faults requires grouped dispatch (OEA_DISPATCH=grouped); the gather \
                 oracle has no per-expert work list to inject into"
                    .into(),
            ));
        }
        backend.install_faults(oea_serve::faults::FaultPlan::parse(spec)?);
    }
    Ok(ModelRunner::new(backend))
}

fn run_cpu(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("serve") => {
            let mut runner = cpu_runner(args)?;
            let cfg_name = runner.cfg().name.clone();
            let tok = cpu_tokenizer(args, &cfg_name);
            let tracer = tracer_from_args(args);
            if let Some(tr) = &tracer {
                runner.backend.install_tracer(std::sync::Arc::clone(tr));
                println!("flight recorder armed (GET /trace)");
            }
            let mut ecfg = engine_config(args, runner.cfg())?;
            scale_page_in(&mut ecfg, runner.backend.panel_dtype());
            ecfg.tracer = tracer.clone();
            let (addr, mut opts) = serve_preamble(args, runner.cfg(), "cpu")?;
            opts.tracer = tracer;
            opts.trace_out = args.str_opt("trace-out").map(String::from);
            server::serve(move || Engine::new(runner, ecfg), tok, &addr, opts)
        }
        Some("generate") => {
            let runner = cpu_runner(args)?;
            let tok = cpu_tokenizer(args, &runner.cfg().name.clone());
            let mut ecfg = engine_config(args, runner.cfg())?;
            scale_page_in(&mut ecfg, runner.backend.panel_dtype());
            cmd_generate(args, runner, tok, ecfg)
        }
        Some("ce-eval") => {
            let runner = cpu_runner(args)?;
            let tok = cpu_tokenizer(args, &runner.cfg().name.clone());
            cmd_ce_eval(args, runner, tok)
        }
        Some("info") => cmd_info(cpu_runner(args)?),
        other => Err(oea_serve::Error::Config(format!(
            "unknown subcommand {other:?}; try serve | generate | ce-eval | info"
        ))),
    }
}

// ---- PJRT backend (feature-gated) ----------------------------------------

#[cfg(feature = "pjrt")]
fn run_pjrt(args: &Args) -> Result<()> {
    use oea_serve::backend::pjrt::PjrtBackend;

    let root = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let cfg_name = args.str_or("config", "small");
    match args.subcommand.as_deref() {
        Some("serve") => {
            // validate flags + resolve the vocab WITHOUT creating a PJRT
            // client: xla_extension 0.5.1 cannot survive a create/destroy/
            // create cycle of TfrtCpuClient in one process, so only the
            // engine thread makes one.
            let manifest = oea_serve::config::Manifest::load(&root, &cfg_name)?;
            let tok = Tokenizer::load(&manifest.dir.join(&manifest.vocab_file))?;
            let tracer = tracer_from_args(args);
            let (addr, mut opts) = serve_preamble(args, &manifest.config, "pjrt")?;
            opts.tracer = tracer.clone();
            opts.trace_out = args.str_opt("trace-out").map(String::from);
            let args2 = args.clone();
            server::serve(
                move || {
                    let runner = ModelRunner::new(PjrtBackend::load(&root, &cfg_name)?);
                    let mut ecfg = engine_config(&args2, runner.cfg())?;
                    ecfg.tracer = tracer;
                    Engine::new(runner, ecfg)
                },
                tok,
                &addr,
                opts,
            )
        }
        Some("generate") => {
            let runner = ModelRunner::new(PjrtBackend::load(&root, &cfg_name)?);
            let m = &runner.backend.rt.manifest;
            let tok = Tokenizer::load(&m.dir.join(&m.vocab_file))?;
            let ecfg = engine_config(args, runner.cfg())?;
            cmd_generate(args, runner, tok, ecfg)
        }
        Some("ce-eval") => {
            let runner = ModelRunner::new(PjrtBackend::load(&root, &cfg_name)?);
            let m = &runner.backend.rt.manifest;
            let tok = Tokenizer::load(&m.dir.join(&m.vocab_file))?;
            cmd_ce_eval(args, runner, tok)
        }
        Some("info") => cmd_info(ModelRunner::new(PjrtBackend::load(&root, &cfg_name)?)),
        other => Err(oea_serve::Error::Config(format!(
            "unknown subcommand {other:?}; try serve | generate | ce-eval | info"
        ))),
    }
}

#[cfg(not(feature = "pjrt"))]
fn run_pjrt(_args: &Args) -> Result<()> {
    Err(oea_serve::Error::Config(
        "this build has no PJRT support; rebuild with `cargo build --features pjrt` \
         (and patch in the real xla crate — see README, \"PJRT backend\")"
            .into(),
    ))
}
