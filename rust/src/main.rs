//! `oea-serve` launcher.
//!
//! Subcommands:
//!   serve     start the HTTP serving frontend
//!   generate  one-shot generation from the command line
//!   ce-eval   teacher-forced CE comparison of a policy vs vanilla
//!   info      print manifest / config / router stats
//!
//! Examples:
//!   oea-serve serve --config small --policy oea:k0=3 --max-running 16 \
//!       --port 8080
//!   oea-serve generate --config small --policy oea:k0=3 \
//!       --prompt "The quiet river" --max-tokens 32
//!   oea-serve ce-eval --config small --policy pruned:k0=3 --batch 16

use std::path::PathBuf;
use std::process::ExitCode;

use oea_serve::coordinator::{Engine, EngineConfig, GenRequest};
use oea_serve::eval;
use oea_serve::latency::H100Presets;
use oea_serve::model::ModelRunner;
use oea_serve::moe::policy::Policy;
use oea_serve::runtime::Runtime;
use oea_serve::server;
use oea_serve::util::bpe::Tokenizer;
use oea_serve::util::cli::{Args, Spec};
use oea_serve::util::corpus::Corpus;
use oea_serve::util::error::Result;
use oea_serve::util::rng::Rng;

fn spec() -> Spec {
    Spec {
        name: "oea-serve",
        about: "MoE serving with Opportunistic Expert Activation (OEA) routing",
        options: vec![
            ("config", true, "model config: tiny | small | base (default small)"),
            ("artifacts", true, "artifact root (default ./artifacts)"),
            ("data", true, "corpus dir (default ./data)"),
            ("policy", true, "routing policy, e.g. vanilla, pruned:k0=3, oea:k0=3, \
                              oea-full:k0=3,p=0.7,kmax=9,maxp=32, lynx:t=16, dynskip:tau=0.3"),
            ("max-running", true, "max concurrent requests (default 8)"),
            ("port", true, "serve: TCP port (default 8080)"),
            ("max-requests", true, "serve: exit after N generations (default: run forever)"),
            ("no-mask-padding", false, "disable the padding-token routing fix (paper §6)"),
            ("prompt", true, "generate: prompt text"),
            ("max-tokens", true, "generate: tokens to generate (default 32)"),
            ("temperature", true, "sampling temperature (default 0)"),
            ("top-p", true, "nucleus threshold (default 1.0)"),
            ("batch", true, "ce-eval: batch size (default 16)"),
            ("positions", true, "ce-eval: decode positions (default 48)"),
            ("mixed", false, "ce-eval: mixed-domain batches (default: domain-pure)"),
            ("seed", true, "rng seed (default 0)"),
        ],
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.iter().any(|a| a == "--help" || a == "-h") || argv.is_empty() {
        print!("{}", spec().usage());
        println!("\nsubcommands: serve | generate | ce-eval | info");
        return ExitCode::SUCCESS;
    }
    match run(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn load_runner(args: &Args) -> Result<ModelRunner> {
    let root = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let cfg = args.str_or("config", "small");
    let rt = Runtime::load(&root, &cfg)?;
    Ok(ModelRunner::new(rt))
}

fn parse_policy(args: &Args, runner: &ModelRunner) -> Result<Policy> {
    let c = runner.cfg();
    Policy::from_cli(&args.str_or("policy", "vanilla"), c.top_k, c.n_experts)
}

fn make_engine(args: &Args, runner: ModelRunner) -> Result<Engine> {
    let policy = parse_policy(args, &runner)?;
    let preset = H100Presets::for_config(&runner.cfg().name);
    Engine::new(
        runner,
        EngineConfig {
            policy,
            mask_padding: !args.flag("no-mask-padding"),
            max_running: args.usize_or("max-running", 8)?,
            eos_token: None,
            cost_model: preset,
        },
    )
}

fn run(argv: &[String]) -> Result<()> {
    let args = spec().parse(argv, true)?;
    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("ce-eval") => cmd_ce_eval(&args),
        Some("info") => cmd_info(&args),
        other => Err(oea_serve::Error::Config(format!(
            "unknown subcommand {other:?}; try serve | generate | ce-eval | info"
        ))),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    // validate flags + resolve the vocab WITHOUT creating a PJRT client:
    // xla_extension 0.5.1 cannot survive a create/destroy/create cycle of
    // TfrtCpuClient in one process, so only the engine thread makes one.
    let root = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let cfg_name = args.str_or("config", "small");
    let manifest = oea_serve::config::Manifest::load(&root, &cfg_name)?;
    let tok = Tokenizer::load(&manifest.dir.join(&manifest.vocab_file))?;
    let policy = Policy::from_cli(
        &args.str_or("policy", "vanilla"),
        manifest.config.top_k,
        manifest.config.n_experts,
    )?;
    let port = args.usize_or("port", 8080)?;
    let max_requests = match args.str_opt("max-requests") {
        Some(_) => Some(args.usize_or("max-requests", 0)?),
        None => None,
    };
    println!(
        "serving config={} policy={} max_running={} on 127.0.0.1:{port}",
        manifest.config.name,
        policy.label(),
        args.usize_or("max-running", 8)?,
    );
    let args2 = args.clone();
    server::serve(
        move || {
            let runner = load_runner(&args2)?;
            make_engine(&args2, runner)
        },
        tok,
        &format!("127.0.0.1:{port}"),
        max_requests,
    )
}

fn cmd_generate(args: &Args) -> Result<()> {
    let runner = load_runner(args)?;
    let vocab_path = runner.rt.manifest.dir.join(&runner.rt.manifest.vocab_file);
    let tok = Tokenizer::load(&vocab_path)?;
    let prompt_text = args.str_or("prompt", "The quiet river carried the");
    let prompt: Vec<i32> = tok.encode(&prompt_text).iter().map(|&t| t as i32).collect();
    let mut engine = make_engine(args, runner)?;
    engine.submit(GenRequest {
        id: 1,
        prompt,
        max_new_tokens: args.usize_or("max-tokens", 32)?,
        temperature: args.f64_or("temperature", 0.0)? as f32,
        top_p: args.f64_or("top-p", 1.0)? as f32,
        seed: args.usize_or("seed", 0)? as u64,
    });
    let done = engine.run_to_completion()?;
    for f in done {
        let text = tok.decode(&f.tokens.iter().map(|&t| t as u32).collect::<Vec<_>>());
        println!("--- request {} ({:?}, {} tokens)", f.id, f.reason, f.tokens.len());
        println!("{prompt_text}{text}");
    }
    println!(
        "\navg active experts: {:.1}  simulated MoE latency: {:.1} us  \
         measured MoE latency: {:.1} us",
        engine.moe.avg_t(),
        engine.moe.avg_latency_us(true),
        engine.moe.avg_latency_us(false),
    );
    Ok(())
}

fn cmd_ce_eval(args: &Args) -> Result<()> {
    let runner = load_runner(args)?;
    let policy = parse_policy(args, &runner)?;
    let corpus = Corpus::load(&PathBuf::from(args.str_or("data", "data")))?;
    let vocab_path = runner.rt.manifest.dir.join(&runner.rt.manifest.vocab_file);
    let tok = Tokenizer::load(&vocab_path)?;
    let mut rng = Rng::new(args.usize_or("seed", 0)? as u64);
    let b = args.usize_or("batch", 16)?;
    let positions = args.usize_or("positions", 48)?;
    let seqs =
        eval::sequences_from_corpus(&corpus, &tok, &mut rng, b, positions, args.flag("mixed"));

    let k = runner.cfg().top_k;
    let vanilla = eval::forced_run(&runner, &seqs, positions, Policy::Vanilla { k }, true)?;
    let run = eval::forced_run(&runner, &seqs, positions, policy, true)?;
    let r = eval::ce_compare(&seqs, &run, &vanilla);
    println!(
        "policy={} B={b} positions={positions}\n  ce={:.4} ce_delta={:+.4} kl={:.5}\n  \
         avg_active_experts={:.2} (vanilla {:.2})  avg_moe_us_measured={:.1}",
        policy.label(),
        r.ce,
        r.ce_delta,
        r.kl_vanilla,
        r.avg_t,
        vanilla.avg_t,
        r.avg_moe_us,
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let runner = load_runner(args)?;
    let c = runner.cfg();
    println!("config: {c:#?}");
    println!("stages: {}", runner.rt.manifest.stages.len());
    println!("weights: {}", runner.rt.weight_names().len());
    Ok(())
}
