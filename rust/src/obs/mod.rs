//! Observability plane: flight-recorder tracing + metrics exposition.
//!
//! Three pieces, all dependency-free and offline:
//!
//! - [`Tracer`] / [`SpanGuard`] ([`trace`]): a lock-cheap bounded flight
//!   recorder. Spans and instant events land in a drop-oldest ring with a
//!   hard entry *and* byte cap, timestamped in microseconds off one
//!   monotone clock, and export as Chrome trace-event JSON loadable in
//!   Perfetto / `chrome://tracing` (`GET /trace`, `--trace-out`). The
//!   disabled path is `Option<Arc<Tracer>> = None` everywhere — no ring,
//!   no clock reads, bitwise-identical engine output (property-tested in
//!   `tests/obs_properties.rs`).
//! - [`EventLog`]: the one bounded drop-oldest event ledger. Both the
//!   fault plane (`faults::FaultState`) and the SLO controller
//!   (`coordinator::controller`) feed their `DegradationEvent`s through
//!   it; the tracer renders the same events as instants so `/trace` and
//!   `/metrics` tell one story.
//! - [`prometheus_text`] ([`prom`]): renders the engine's metrics JSON
//!   (every block: slo, classes, scheduler, ep, residency, health,
//!   faults, controller, build_info) as Prometheus text exposition for
//!   `GET /metrics?format=prometheus`.

pub mod prom;
pub mod trace;

pub use prom::prometheus_text;
pub use trace::{SpanGuard, Tracer, BACKEND_TID, ENGINE_TID, EVENTS_TID};

/// Default bound for [`EventLog`]: large enough to audit a degradation
/// cascade, small enough to never matter for memory.
pub const EVENT_LOG_BOUND: usize = 128;

/// A bounded, drop-oldest event ledger.
///
/// This is the single implementation behind the fault plane's
/// `DegradationEvent` log and the SLO controller's decision log (both
/// previously hand-rolled the same `push_event` + bound). Pushing past
/// the bound silently drops the oldest entry; [`EventLog::dropped`]
/// counts how many were lost so exports can say "…and N earlier events".
#[derive(Debug, Clone)]
pub struct EventLog<T> {
    items: std::collections::VecDeque<T>,
    bound: usize,
    dropped: u64,
}

impl<T> Default for EventLog<T> {
    fn default() -> Self {
        Self::new(EVENT_LOG_BOUND)
    }
}

impl<T> EventLog<T> {
    pub fn new(bound: usize) -> Self {
        assert!(bound >= 1, "event log bound must be >= 1");
        EventLog { items: std::collections::VecDeque::with_capacity(bound.min(64)), bound, dropped: 0 }
    }

    /// Append, evicting the oldest entry when full.
    pub fn push(&mut self, item: T) {
        if self.items.len() >= self.bound {
            self.items.pop_front();
            self.dropped += 1;
        }
        self.items.push_back(item);
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// How many entries were evicted to stay under the bound.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn bound(&self) -> usize {
        self.bound
    }

    pub fn iter(&self) -> impl DoubleEndedIterator<Item = &T> + ExactSizeIterator {
        self.items.iter()
    }

    pub fn last(&self) -> Option<&T> {
        self.items.back()
    }
}

impl<T: Clone> EventLog<T> {
    /// Snapshot oldest-first (the shape the metrics serializers expect).
    pub fn to_vec(&self) -> Vec<T> {
        self.items.iter().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_log_drops_oldest_at_bound() {
        let mut log = EventLog::new(3);
        for i in 0..10 {
            log.push(i);
        }
        assert_eq!(log.len(), 3);
        assert_eq!(log.to_vec(), vec![7, 8, 9]);
        assert_eq!(log.dropped(), 7);
        assert_eq!(log.last(), Some(&9));
    }

    #[test]
    fn event_log_default_bound_matches_constant() {
        let mut log: EventLog<u32> = EventLog::default();
        assert_eq!(log.bound(), EVENT_LOG_BOUND);
        for i in 0..(EVENT_LOG_BOUND as u32 * 2) {
            log.push(i);
        }
        assert_eq!(log.len(), EVENT_LOG_BOUND);
        assert_eq!(*log.iter().next().unwrap(), EVENT_LOG_BOUND as u32);
    }

    #[test]
    #[should_panic(expected = "bound must be >= 1")]
    fn event_log_rejects_zero_bound() {
        let _ = EventLog::<u32>::new(0);
    }
}
