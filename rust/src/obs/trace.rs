//! The flight recorder: bounded span/event ring + Chrome trace export.
//!
//! One [`Tracer`] is shared (behind `Arc`) by the engine thread, the HTTP
//! workers, and the CPU backend. Recording is a single short mutex
//! critical section per event — timestamps are drawn inside the lock so
//! ring order is timestamp order for plain `begin`/`end`/`instant`
//! (only `begin_at`, used to backdate a span around already-measured
//! work, can land out of order; export sorts). The ring enforces a hard
//! entry cap AND byte cap by dropping the oldest entries, so a tracer
//! left on under production traffic holds the last N microseconds of
//! history instead of growing without bound — a flight recorder, not a
//! log.
//!
//! Export is the Chrome trace-event JSON format: `B`/`E` duration pairs
//! matched per `tid`, `i` instants, microsecond `ts`. Spans whose
//! opening half was evicted (or that are still open) are filtered out at
//! export time so the emitted JSON always has balanced, nested pairs.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// Track ids: Chrome groups B/E pairs per (pid, tid). Request-lifecycle
/// spans (queue, prefill) use `REQ_TID_BASE + request id` so each request
/// renders as its own row; these three host everything else.
pub const ENGINE_TID: u64 = 0;
pub const BACKEND_TID: u64 = 1;
pub const EVENTS_TID: u64 = 2;
/// Offset request-id tracks clear of the fixed tracks above.
pub const REQ_TID_BASE: u64 = 10;

/// Default caps: plenty for minutes of decode traffic, bounded at a few
/// MiB of resident history.
pub const DEFAULT_MAX_ENTRIES: usize = 65_536;
pub const DEFAULT_MAX_BYTES: usize = 8 << 20;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ph {
    Begin,
    End,
    Instant,
}

impl Ph {
    fn chrome(self) -> &'static str {
        match self {
            Ph::Begin => "B",
            Ph::End => "E",
            Ph::Instant => "i",
        }
    }
}

#[derive(Debug, Clone)]
struct Entry {
    name: &'static str,
    ph: Ph,
    ts_us: u64,
    tid: u64,
    args: Vec<(&'static str, Json)>,
    bytes: usize,
}

/// Rough serialized size of one entry — what the byte cap meters.
fn entry_bytes(name: &str, args: &[(&'static str, Json)]) -> usize {
    let mut b = 48 + name.len();
    for (k, v) in args {
        b += k.len()
            + 4
            + match v {
                Json::Num(_) => 12,
                Json::Bool(_) => 5,
                Json::Null => 4,
                Json::Str(s) => s.len() + 2,
                other => other.write().len(),
            };
    }
    b
}

#[derive(Debug, Default)]
struct Ring {
    items: VecDeque<Entry>,
    bytes: usize,
    dropped: u64,
}

/// Thread-safe bounded flight recorder. Cheap enough to leave on: one
/// mutex lock and a couple of small allocations per recorded event, and
/// the fully-disabled path is simply not having a `Tracer` at all
/// (`Option<Arc<Tracer>> = None`), which executes zero instructions.
#[derive(Debug)]
pub struct Tracer {
    t0: Instant,
    max_entries: usize,
    max_bytes: usize,
    ring: Mutex<Ring>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::with_caps(DEFAULT_MAX_ENTRIES, DEFAULT_MAX_BYTES)
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer::default()
    }

    pub fn with_caps(max_entries: usize, max_bytes: usize) -> Tracer {
        assert!(max_entries >= 1 && max_bytes >= 1, "tracer caps must be >= 1");
        Tracer { t0: Instant::now(), max_entries, max_bytes, ring: Mutex::new(Ring::default()) }
    }

    /// Microseconds since the tracer was created (the trace's epoch).
    pub fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    fn push(&self, name: &'static str, ph: Ph, ts_us: Option<u64>, tid: u64, args: Vec<(&'static str, Json)>) {
        let bytes = entry_bytes(name, &args);
        let mut ring = match self.ring.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if bytes > self.max_bytes {
            ring.dropped += 1;
            return;
        }
        // draw ts inside the lock so ring order == timestamp order
        let ts_us = ts_us.unwrap_or_else(|| self.now_us());
        while !ring.items.is_empty()
            && (ring.items.len() >= self.max_entries || ring.bytes + bytes > self.max_bytes)
        {
            let old = ring.items.pop_front().expect("non-empty ring");
            ring.bytes -= old.bytes;
            ring.dropped += 1;
        }
        ring.bytes += bytes;
        ring.items.push_back(Entry { name, ph, ts_us, tid, args, bytes });
    }

    /// Open a span on track `tid`.
    pub fn begin(&self, name: &'static str, tid: u64, args: Vec<(&'static str, Json)>) {
        self.push(name, Ph::Begin, None, tid, args);
    }

    /// Open a span backdated to `ts_us` (from [`Tracer::now_us`]) — for
    /// spans whose duration was measured before the args were known.
    pub fn begin_at(&self, name: &'static str, tid: u64, ts_us: u64, args: Vec<(&'static str, Json)>) {
        self.push(name, Ph::Begin, Some(ts_us), tid, args);
    }

    /// Close the innermost open span named `name` on track `tid`.
    pub fn end(&self, name: &'static str, tid: u64) {
        self.push(name, Ph::End, None, tid, Vec::new());
    }

    /// Record a zero-duration instant event.
    pub fn instant(&self, name: &'static str, tid: u64, args: Vec<(&'static str, Json)>) {
        self.push(name, Ph::Instant, None, tid, args);
    }

    /// RAII span: `B` now, `E` when the guard drops.
    pub fn span(self: &Arc<Self>, name: &'static str, tid: u64, args: Vec<(&'static str, Json)>) -> SpanGuard {
        self.begin(name, tid, args);
        SpanGuard { tracer: Arc::clone(self), name, tid }
    }

    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().items.is_empty()
    }

    /// Estimated bytes currently held by the ring.
    pub fn bytes(&self) -> usize {
        self.lock().bytes
    }

    /// Events evicted (or refused) to honor the caps.
    pub fn dropped(&self) -> u64 {
        self.lock().dropped
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Ring> {
        match self.ring.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Export as Chrome trace-event JSON (`{"traceEvents": [...]}`),
    /// loadable in Perfetto / `chrome://tracing`. Events are sorted by
    /// timestamp and unbalanced `B`/`E` halves (evicted or still-open
    /// spans) are dropped so every emitted pair matches.
    pub fn chrome_trace(&self) -> Json {
        let (entries, dropped) = {
            let ring = self.lock();
            (ring.items.iter().cloned().collect::<Vec<_>>(), ring.dropped)
        };
        let mut order: Vec<usize> = (0..entries.len()).collect();
        order.sort_by_key(|&i| entries[i].ts_us);
        // match B/E pairs per tid; unmatched halves are filtered out
        let mut keep = vec![false; entries.len()];
        let mut stacks: HashMap<u64, Vec<usize>> = HashMap::new();
        for &i in &order {
            let e = &entries[i];
            match e.ph {
                Ph::Instant => keep[i] = true,
                Ph::Begin => stacks.entry(e.tid).or_default().push(i),
                Ph::End => {
                    if let Some(stack) = stacks.get_mut(&e.tid) {
                        if let Some(&j) = stack.last() {
                            if entries[j].name == e.name {
                                stack.pop();
                                keep[i] = true;
                                keep[j] = true;
                            }
                        }
                    }
                }
            }
        }
        let events: Vec<Json> = order
            .iter()
            .filter(|&&i| keep[i])
            .map(|&i| {
                let e = &entries[i];
                let mut fields = vec![
                    ("name", Json::str(e.name)),
                    ("cat", Json::str("oea")),
                    ("ph", Json::str(e.ph.chrome())),
                    ("ts", Json::num(e.ts_us as f64)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(e.tid as f64)),
                ];
                if e.ph == Ph::Instant {
                    fields.push(("s", Json::str("t")));
                }
                if !e.args.is_empty() {
                    fields.push((
                        "args",
                        Json::obj(e.args.iter().map(|(k, v)| (*k, v.clone())).collect()),
                    ));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            ("droppedEvents", Json::num(dropped as f64)),
        ])
    }
}

/// Closes its span when dropped (panic-safe: an unwinding scope still
/// emits its `E`, keeping exports balanced).
pub struct SpanGuard {
    tracer: Arc<Tracer>,
    name: &'static str,
    tid: u64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        self.tracer.end(self.name, self.tid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn events(t: &Tracer) -> Vec<Json> {
        t.chrome_trace().get("traceEvents").unwrap().as_arr().unwrap().to_vec()
    }

    #[test]
    fn span_pairs_export_balanced_and_monotone() {
        let t = Arc::new(Tracer::new());
        {
            let _g = t.span("decode_step", ENGINE_TID, vec![("live_b", Json::num(4.0))]);
            t.instant("page_in", BACKEND_TID, vec![("expert", Json::num(3.0))]);
        }
        let ev = events(&t);
        assert_eq!(ev.len(), 3);
        let phs: Vec<&str> = ev.iter().map(|e| e.get("ph").unwrap().as_str().unwrap()).collect();
        assert_eq!(phs.iter().filter(|p| **p == "B").count(), 1);
        assert_eq!(phs.iter().filter(|p| **p == "E").count(), 1);
        let ts: Vec<f64> = ev.iter().map(|e| e.get("ts").unwrap().as_f64().unwrap()).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "ts must be monotone: {ts:?}");
        assert_eq!(
            ev[0].get("args").unwrap().get("live_b").unwrap().as_f64().unwrap(),
            4.0
        );
    }

    #[test]
    fn open_span_is_filtered_from_export() {
        let t = Tracer::new();
        t.begin("queue", 7, vec![]);
        t.instant("mark", EVENTS_TID, vec![]);
        let ev = events(&t);
        assert_eq!(ev.len(), 1, "dangling B must not export: {ev:?}");
        assert_eq!(ev[0].get("name").unwrap().as_str().unwrap(), "mark");
    }

    #[test]
    fn entry_cap_drops_oldest() {
        let t = Tracer::with_caps(4, usize::MAX >> 1);
        for _ in 0..10 {
            t.instant("x", 0, vec![]);
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped(), 6);
    }

    #[test]
    fn byte_cap_is_enforced() {
        let t = Tracer::with_caps(usize::MAX >> 1, 400);
        for _ in 0..100 {
            t.instant("some_event_name", 0, vec![("k", Json::num(1.0))]);
        }
        assert!(t.bytes() <= 400, "bytes {} over cap", t.bytes());
        assert!(t.len() < 100);
        // an entry alone larger than the cap is refused outright
        let big = "x".repeat(1000);
        t.instant("big", 0, vec![("v", Json::str(&big))]);
        assert!(t.bytes() <= 400);
    }

    #[test]
    fn truncated_end_is_dropped_not_mismatched() {
        // evict the B of a pair; its E must not pair with a later span
        let t = Tracer::with_caps(3, usize::MAX >> 1);
        t.begin("a", 0, vec![]);
        t.end("a", 0); // pair 1 complete
        t.begin("b", 0, vec![]);
        t.end("b", 0); // pushes "a"'s B out (cap 3)
        let ev = events(&t);
        let names: Vec<&str> = ev.iter().map(|e| e.get("name").unwrap().as_str().unwrap()).collect();
        assert_eq!(names, vec!["b", "b"], "only the intact pair survives: {names:?}");
    }

    #[test]
    fn backdated_begin_sorts_into_place() {
        let t = Tracer::new();
        let before = t.now_us();
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.instant("early", 0, vec![]);
        t.begin_at("work", 1, before, vec![("load", Json::num(9.0))]);
        t.end("work", 1);
        let ev = events(&t);
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].get("name").unwrap().as_str().unwrap(), "work");
    }
}
