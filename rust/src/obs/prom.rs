//! Prometheus text-exposition renderer for the metrics JSON.
//!
//! `GET /metrics` defaults to the JSON document; `?format=prometheus`
//! (or `Accept: text/plain`) routes through [`prometheus_text`], which
//! walks that same JSON generically so every block — top-level, slo,
//! classes, scheduler, ep, residency, health, faults, controller,
//! build_info, and anything a future PR adds — round-trips into
//! well-formed exposition text without a per-field mapping to maintain:
//!
//! - numbers → `oea_<block>_<field>` gauge, or counter when the field
//!   name is a known monotone ledger (`n_*`, `steps`, `hits`, …)
//! - `{p50,p95,p99,n}` percentile objects → a summary with
//!   `quantile` labels + `_count`
//! - bools → 0/1 gauges
//! - strings → `_info` gauges carrying the value as a label
//!   (`oea_policy_info{policy="oea:k0=4,k=8"} 1`)
//! - arrays of numbers → one series per element, labeled `index`
//! - arrays of objects → labeled by their identity key
//!   (`expert`/`rank`/`layer`), one metric per numeric field
//! - event ledgers (objects carrying a `detail` string) are skipped —
//!   they are timeline data and export via `/trace` instead
//!
//! `# TYPE` is emitted exactly once per metric name and all samples of
//! a name are contiguous, as the exposition format requires.

use std::collections::BTreeSet;

use crate::util::json::Json;

/// Monotone-ledger field names rendered as `counter` (everything else
/// numeric is a `gauge`). `n_*` is handled by prefix.
const COUNTER_KEYS: &[&str] = &[
    "steps",
    "decode_steps",
    "admitted",
    "recompositions",
    "prefill_chunks",
    "prefill_tokens",
    "generated_tokens",
    "evals",
    "tightens",
    "relaxes",
    "holds",
    "hits",
    "misses",
    "evictions",
    "bytes_paged",
    "prefetches",
    "panics_caught",
    "nonfinite_rows",
    "deadline_expired",
    "wedged_steps",
    "degraded_tokens",
    "routed_tokens_masked",
    "pagein_failures",
    "pagein_retries",
    "pagein_gave_up",
    "pagein_delays",
    "injected_sleep_us",
    "stalls",
    "stall_us_total",
    "poisoned_outputs",
    "panics",
    "tripped_experts",
    "probation_readmitted",
    "probation_retrips",
    "rank_up_recovered",
    "events_dropped",
];

fn is_counter(key: &str) -> bool {
    key.starts_with("n_") || COUNTER_KEYS.contains(&key)
}

/// `{p50,p95,p99,n}` — the shape `metrics::percentiles_ms` emits.
fn is_percentiles(v: &Json) -> bool {
    matches!(v, Json::Obj(m)
        if ["p50", "p95", "p99", "n"].iter().all(|k| matches!(m.get(*k), Some(Json::Num(_))))
            && m.len() == 4)
}

/// Identity key labeling an array-of-objects series.
fn label_key(m: &std::collections::BTreeMap<String, Json>) -> Option<&'static str> {
    ["expert", "rank", "layer"].into_iter().find(|k| matches!(m.get(*k), Some(Json::Num(_))))
}

fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, c) in name.chars().enumerate() {
        if c.is_ascii_alphanumeric() || c == '_' {
            if i == 0 && c.is_ascii_digit() {
                out.push('_');
            }
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn fmt_num(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x.is_infinite() {
        // the exposition format spells infinities +Inf / -Inf
        if x > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

struct Out {
    text: String,
    typed: BTreeSet<String>,
}

impl Out {
    /// Emit the `# TYPE` header once per metric name. Returns false (and
    /// emits nothing) if the name was already typed — callers skip the
    /// sample rather than violate the exposition grammar.
    fn typ(&mut self, name: &str, ty: &str) -> bool {
        if !self.typed.insert(name.to_string()) {
            return false;
        }
        self.text.push_str(&format!("# TYPE {name} {ty}\n"));
        true
    }

    fn sample(&mut self, name: &str, labels: &str, value: f64) {
        self.text.push_str(&format!("{name}{labels} {}\n", fmt_num(value)));
    }
}

/// Render a metrics JSON document as Prometheus text exposition,
/// namespaced under `oea_`.
pub fn prometheus_text(metrics: &Json) -> String {
    let mut out = Out { text: String::new(), typed: BTreeSet::new() };
    emit_value(&mut out, "oea", metrics);
    out.text
}

fn emit_value(out: &mut Out, prefix: &str, v: &Json) {
    match v {
        Json::Obj(m) => {
            for (k, v) in m {
                let name = format!("{prefix}_{}", sanitize(k));
                match v {
                    Json::Num(n) => {
                        let ty = if is_counter(k) { "counter" } else { "gauge" };
                        if out.typ(&name, ty) {
                            out.sample(&name, "", *n);
                        }
                    }
                    Json::Bool(b) => {
                        if out.typ(&name, "gauge") {
                            out.sample(&name, "", if *b { 1.0 } else { 0.0 });
                        }
                    }
                    Json::Str(s) => {
                        let iname = format!("{name}_info");
                        if out.typ(&iname, "gauge") {
                            let lbl = format!("{{{}=\"{}\"}}", sanitize(k), escape_label(s));
                            out.sample(&iname, &lbl, 1.0);
                        }
                    }
                    Json::Null => {}
                    _ if is_percentiles(v) => emit_percentiles(out, &name, v),
                    Json::Obj(inner) if k == "build_info" => emit_build_info(out, &name, inner),
                    Json::Obj(_) => emit_value(out, &name, v),
                    Json::Arr(items) => emit_array(out, &name, items),
                }
            }
        }
        Json::Arr(items) => emit_array(out, prefix, items),
        _ => {}
    }
}

fn emit_percentiles(out: &mut Out, name: &str, v: &Json) {
    if !out.typ(name, "summary") {
        return;
    }
    for (q, key) in [("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99")] {
        if let Some(Json::Num(x)) = v.get_opt(key) {
            out.sample(name, &format!("{{quantile=\"{q}\"}}"), *x);
        }
    }
    let count = format!("{name}_count");
    if let Some(Json::Num(n)) = v.get_opt("n") {
        if out.typ(&count, "counter") {
            out.sample(&count, "", *n);
        }
    }
}

/// `build_info` gets the idiomatic Prometheus treatment: one `*_info`
/// gauge whose string fields become labels, numeric fields as plain
/// gauges beside it.
fn emit_build_info(out: &mut Out, name: &str, m: &std::collections::BTreeMap<String, Json>) {
    let labels: Vec<String> = m
        .iter()
        .filter_map(|(k, v)| match v {
            Json::Str(s) => Some(format!("{}=\"{}\"", sanitize(k), escape_label(s))),
            _ => None,
        })
        .collect();
    if out.typ(name, "gauge") {
        let lbl = if labels.is_empty() { String::new() } else { format!("{{{}}}", labels.join(",")) };
        out.sample(name, &lbl, 1.0);
    }
    for (k, v) in m {
        if let Json::Num(n) = v {
            let fname = format!("{name}_{}", sanitize(k));
            let ty = if is_counter(k) { "counter" } else { "gauge" };
            if out.typ(&fname, ty) {
                out.sample(&fname, "", *n);
            }
        }
    }
}

fn emit_array(out: &mut Out, name: &str, items: &[Json]) {
    if items.is_empty() {
        return;
    }
    match &items[0] {
        Json::Num(_) => {
            if !out.typ(name, "gauge") {
                return;
            }
            for (i, v) in items.iter().enumerate() {
                if let Json::Num(n) = v {
                    out.sample(name, &format!("{{index=\"{i}\"}}"), *n);
                }
            }
        }
        Json::Obj(first) => {
            // event ledgers export via /trace, not as metrics
            if first.contains_key("detail") {
                return;
            }
            let label = match label_key(first) {
                Some(l) => l,
                None => return,
            };
            // fields outer, elements inner: all samples of one metric
            // name must be contiguous in the exposition text
            let fields: Vec<&String> = first
                .iter()
                .filter(|(k, v)| k.as_str() != label && matches!(v, Json::Num(_)))
                .map(|(k, _)| k)
                .collect();
            for field in fields {
                let fname = format!("{name}_{}", sanitize(field));
                let ty = if is_counter(field) { "counter" } else { "gauge" };
                if !out.typ(&fname, ty) {
                    continue;
                }
                for item in items {
                    let (Some(Json::Num(id)), Some(Json::Num(val))) =
                        (item.get_opt(label), item.get_opt(field))
                    else {
                        continue;
                    };
                    out.sample(&fname, &format!("{{{label}=\"{}\"}}", fmt_num(*id)), *val);
                }
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn render(src: &str) -> String {
        prometheus_text(&Json::parse(src).unwrap())
    }

    #[test]
    fn numbers_and_counters_typed() {
        let text = render(r#"{"n_finished": 3, "avg_active_experts": 5.5, "scheduler": {"steps": 9, "live_b": 4}}"#);
        assert!(text.contains("# TYPE oea_n_finished counter\noea_n_finished 3\n"));
        assert!(text.contains("# TYPE oea_avg_active_experts gauge\noea_avg_active_experts 5.5\n"));
        assert!(text.contains("# TYPE oea_scheduler_steps counter\noea_scheduler_steps 9\n"));
        assert!(text.contains("# TYPE oea_scheduler_live_b gauge\n"));
    }

    #[test]
    fn percentile_blocks_become_summaries() {
        let text = render(r#"{"slo": {"ttft_ms": {"p50": 1.0, "p95": 2.0, "p99": 3.5, "n": 7}}}"#);
        assert!(text.contains("# TYPE oea_slo_ttft_ms summary\n"));
        assert!(text.contains("oea_slo_ttft_ms{quantile=\"0.5\"} 1\n"));
        assert!(text.contains("oea_slo_ttft_ms{quantile=\"0.99\"} 3.5\n"));
        assert!(text.contains("# TYPE oea_slo_ttft_ms_count counter\noea_slo_ttft_ms_count 7\n"));
    }

    #[test]
    fn strings_become_info_gauges() {
        let text = render(r#"{"policy": "oea:k0=4,k=8"}"#);
        assert!(text.contains("# TYPE oea_policy_info gauge\n"));
        assert!(text.contains("oea_policy_info{policy=\"oea:k0=4,k=8\"} 1\n"));
    }

    #[test]
    fn arrays_are_labeled_series() {
        let text = render(
            r#"{"ep": {"rank_load": [4, 6]},
                "expert_load": {"per_expert": [{"expert": 0, "tokens": 10, "share": 0.4},
                                               {"expert": 1, "tokens": 15, "share": 0.6}]}}"#,
        );
        assert!(text.contains("oea_ep_rank_load{index=\"0\"} 4\n"));
        assert!(text.contains("oea_ep_rank_load{index=\"1\"} 6\n"));
        assert!(text.contains("oea_expert_load_per_expert_tokens{expert=\"1\"} 15\n"));
        assert!(text.contains("oea_expert_load_per_expert_share{expert=\"0\"} 0.4\n"));
    }

    #[test]
    fn event_ledgers_are_skipped() {
        let text = render(
            r#"{"controller": {"tight": 0.8,
                 "events": [{"step": 4, "class": "slo-control", "detail": "tighten"}]}}"#,
        );
        assert!(text.contains("oea_controller_tight 0.8\n"));
        assert!(!text.contains("detail"), "ledger leaked: {text}");
        assert!(!text.contains("events"), "ledger leaked: {text}");
    }

    #[test]
    fn build_info_is_one_labeled_gauge() {
        let text = render(
            r#"{"build_info": {"version": "0.1.0", "backend": "cpu", "features": "default",
                               "uptime_s": 12.5, "steps": 42}}"#,
        );
        assert!(text.contains(
            "oea_build_info{backend=\"cpu\",features=\"default\",version=\"0.1.0\"} 1\n"
        ));
        assert!(text.contains("# TYPE oea_build_info_uptime_s gauge\noea_build_info_uptime_s 12.5\n"));
        assert!(text.contains("# TYPE oea_build_info_steps counter\noea_build_info_steps 42\n"));
    }

    #[test]
    fn no_duplicate_type_lines() {
        let text = render(
            r#"{"a": {"hits": 1}, "b": {"hits": 2}, "slo": {"e2e_ms": {"p50":1,"p95":2,"p99":3,"n":4}}}"#,
        );
        let mut names = BTreeSet::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let name = rest.split_whitespace().next().unwrap();
                assert!(names.insert(name.to_string()), "duplicate TYPE for {name}");
            }
        }
        assert!(names.contains("oea_a_hits") && names.contains("oea_b_hits"));
    }

    #[test]
    fn label_escaping() {
        let text = render(r#"{"plan": "a\"b\\c"}"#);
        assert!(text.contains(r#"oea_plan_info{plan="a\"b\\c"} 1"#));
    }
}
