//! # oea-serve
//!
//! A three-layer (Rust + JAX + Pallas) MoE serving framework reproducing
//! *"Opportunistic Expert Activation: Batch-Aware Expert Routing for Faster
//! Decode Without Retraining"* (CS.LG 2025).
//!
//! Layers:
//! - **L3 (this crate)**: request router, continuous batcher, KV-cache
//!   manager, OEA routing engine, latency model, metrics. Python never runs
//!   on the request path.
//! - **L2** (`python/compile/model.py`): Qwen3-style MoE transformer in JAX,
//!   AOT-lowered to HLO text artifacts loaded by [`runtime`].
//! - **L1** (`python/compile/kernels/`): Pallas kernels (gather-based grouped
//!   expert FFN, router, decode attention) called from L2.

pub mod config;
pub mod coordinator;
pub mod eval;
pub mod latency;
pub mod metrics;
pub mod model;
pub mod moe;
pub mod runtime;
pub mod server;
pub mod util;

pub use util::error::{Error, Result};
