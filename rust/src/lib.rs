//! # oea-serve
//!
//! A MoE serving framework reproducing *"Opportunistic Expert Activation:
//! Batch-Aware Expert Routing for Faster Decode Without Retraining"*
//! (CS.LG 2025).
//!
//! Layers:
//! - **L3 (this crate)**: request router, continuous batcher, KV-cache
//!   manager, OEA routing engine, latency model, metrics. Python never runs
//!   on the request path.
//! - **Backends** ([`backend`]): model execution behind the
//!   [`backend::Backend`] trait. The default, hermetic
//!   [`backend::cpu::CpuBackend`] runs the whole pipeline in pure Rust;
//!   the `pjrt` cargo feature re-enables the PJRT/XLA [`runtime`] that
//!   executes AOT HLO-text artifacts.
//! - **L2** (`python/compile/model.py`): Qwen3-style MoE transformer in
//!   JAX, AOT-lowered to HLO text artifacts (PJRT path only).
//! - **L1** (`python/compile/kernels/`): Pallas kernels (gather-based
//!   grouped expert FFN, router, decode attention) called from L2, with
//!   pure-jnp oracles in `ref.py` that the CPU backend mirrors.

// Index-heavy numeric kernels and telemetry plumbing read clearer with
// explicit loops and full argument lists; keep clippy strict elsewhere.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod backend;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod faults;
pub mod latency;
pub mod metrics;
pub mod model;
pub mod moe;
pub mod obs;
pub mod residency;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod util;

pub use util::error::{Error, Result};
