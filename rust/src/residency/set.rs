//! The per-layer resident-expert set and its eviction policies.

use crate::util::error::{Error, Result};

/// Which resident expert to evict when the set is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Least-recently-used (ties by lower expert id).
    Lru,
    /// Least-frequently-used (ties by LRU, then lower id).
    Lfu,
    /// Lowest router-score EWMA (fed by [`ResidencySet::note_scores`];
    /// ties by LRU, then lower id). Evicts the expert the router has
    /// stopped scoring highly, even if it was touched recently.
    ScoreAware,
}

impl EvictPolicy {
    /// Parse a CLI spec: `lru` | `lfu` | `score`.
    pub fn from_cli(spec: &str) -> Result<EvictPolicy> {
        match spec.trim().to_ascii_lowercase().as_str() {
            "lru" => Ok(EvictPolicy::Lru),
            "lfu" => Ok(EvictPolicy::Lfu),
            "score" => Ok(EvictPolicy::ScoreAware),
            other => Err(Error::Config(format!(
                "unknown eviction policy {other:?} (lru|lfu|score)"
            ))),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            EvictPolicy::Lru => "lru",
            EvictPolicy::Lfu => "lfu",
            EvictPolicy::ScoreAware => "score",
        }
    }
}

/// Outcome of one demand access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Touch {
    /// The expert's panels were already loaded.
    Hit,
    /// The expert had to be paged in, evicting `evicted` if the set was
    /// at capacity.
    Miss { evicted: Option<usize> },
}

/// EWMA smoothing for score-aware eviction: new mass weighs 1/4, so an
/// expert's standing decays over ~a dozen steps of silence.
const SCORE_EWMA: f64 = 0.25;

/// Which experts of one layer are "loaded" under a capacity bound, with
/// the recency/frequency/score state the eviction policies rank by. All
/// tie-breaking is deterministic (recency tick, then expert id), so a
/// trace replays identically.
#[derive(Debug, Clone)]
pub struct ResidencySet {
    n_experts: usize,
    capacity: usize,
    evict: EvictPolicy,
    resident: Vec<bool>,
    n_resident: usize,
    /// monotone access clock (ticks on every demand access)
    tick: u64,
    last_used: Vec<u64>,
    freq: Vec<u64>,
    /// router-mass EWMA per expert (score-aware eviction)
    score: Vec<f64>,
}

impl ResidencySet {
    /// `capacity` is clamped to at least 1 (an empty cache cannot serve).
    pub fn new(n_experts: usize, capacity: usize, evict: EvictPolicy) -> ResidencySet {
        ResidencySet {
            n_experts,
            capacity: capacity.max(1),
            evict,
            resident: vec![false; n_experts],
            n_resident: 0,
            tick: 0,
            last_used: vec![0; n_experts],
            freq: vec![0; n_experts],
            score: vec![0.0; n_experts],
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Unbounded regime: every expert fits, so no eviction ever happens
    /// and every miss is a compulsory first touch. Routing bias toward
    /// residents is disabled here (see `CacheAware` in `moe::policy`) —
    /// with nothing to evict there are no capacity misses to avoid, which
    /// is what makes cache-aware routing at `C >= N` decision-identical
    /// to base OEA.
    pub fn unbounded(&self) -> bool {
        self.capacity >= self.n_experts
    }

    #[inline]
    pub fn contains(&self, e: usize) -> bool {
        self.resident[e]
    }

    pub fn n_resident(&self) -> usize {
        self.n_resident
    }

    /// Per-expert resident flags (the routing view).
    pub fn resident_mask(&self) -> &[bool] {
        &self.resident
    }

    /// One demand access of expert `e`: updates recency/frequency and
    /// pages `e` in on a miss (evicting if at capacity).
    pub fn touch(&mut self, e: usize) -> Touch {
        debug_assert!(e < self.n_experts);
        self.tick += 1;
        self.last_used[e] = self.tick;
        self.freq[e] += 1;
        if self.resident[e] {
            Touch::Hit
        } else {
            Touch::Miss { evicted: self.insert(e) }
        }
    }

    /// Page `e` in without counting a demand access (the prefetch path).
    /// No-op (outer `None`) if already resident; otherwise pages in and
    /// returns the evicted expert, if any. Recency is NOT bumped — a
    /// prefetched-but-never-touched expert must stay first in line for
    /// eviction. `protect` lists experts that must not be chosen as the
    /// victim — the rest of the same prefetch wave, which (all
    /// recency-silent, so maximally stale to the policies) would
    /// otherwise evict each other: at a full cache, admitting the
    /// 2nd-best prediction would throw out the best one just paged in.
    /// Declines the admit (outer `None`) if every resident is protected.
    pub fn admit_protecting(&mut self, e: usize, protect: &[usize]) -> Option<Option<usize>> {
        debug_assert!(e < self.n_experts);
        if self.resident[e] {
            return None;
        }
        if self.n_resident >= self.capacity {
            let v = self.victim_excluding(protect)?;
            self.resident[v] = false;
            self.n_resident -= 1;
            self.resident[e] = true;
            self.n_resident += 1;
            Some(Some(v))
        } else {
            self.resident[e] = true;
            self.n_resident += 1;
            Some(None)
        }
    }

    fn insert(&mut self, e: usize) -> Option<usize> {
        let evicted = if self.n_resident >= self.capacity {
            let v = self
                .victim_excluding(&[])
                .expect("a full unprotected set always has a victim");
            self.resident[v] = false;
            self.n_resident -= 1;
            Some(v)
        } else {
            None
        };
        self.resident[e] = true;
        self.n_resident += 1;
        evicted
    }

    /// The resident expert the active policy ranks lowest, skipping
    /// `protect`; `None` when every resident is protected.
    fn victim_excluding(&self, protect: &[usize]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for e in 0..self.n_experts {
            if !self.resident[e] || protect.contains(&e) {
                continue;
            }
            let b = match best {
                Some(b) => b,
                None => {
                    best = Some(e);
                    continue;
                }
            };
            let worse = match self.evict {
                EvictPolicy::Lru => self.last_used[e] < self.last_used[b],
                EvictPolicy::Lfu => {
                    self.freq[e].cmp(&self.freq[b]).then(self.last_used[e].cmp(&self.last_used[b]))
                        == std::cmp::Ordering::Less
                }
                EvictPolicy::ScoreAware => {
                    self.score[e]
                        .total_cmp(&self.score[b])
                        .then(self.last_used[e].cmp(&self.last_used[b]))
                        == std::cmp::Ordering::Less
                }
            };
            if worse {
                best = Some(e);
            }
        }
        best
    }

    /// Feed one step's batch-aggregated router mass per expert (the
    /// score-aware eviction signal; cheap to maintain under any policy).
    pub fn note_scores(&mut self, agg: &[f32]) {
        debug_assert_eq!(agg.len(), self.n_experts);
        for (s, &a) in self.score.iter_mut().zip(agg.iter()) {
            *s = (1.0 - SCORE_EWMA) * *s + SCORE_EWMA * a as f64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_cli_parses_and_rejects() {
        assert_eq!(EvictPolicy::from_cli("lru").unwrap(), EvictPolicy::Lru);
        assert_eq!(EvictPolicy::from_cli(" LFU ").unwrap(), EvictPolicy::Lfu);
        assert_eq!(EvictPolicy::from_cli("score").unwrap(), EvictPolicy::ScoreAware);
        assert!(EvictPolicy::from_cli("mru").is_err());
        assert_eq!(EvictPolicy::ScoreAware.label(), "score");
    }

    #[test]
    fn misses_then_hits_within_capacity() {
        let mut s = ResidencySet::new(8, 4, EvictPolicy::Lru);
        for e in 0..4 {
            assert_eq!(s.touch(e), Touch::Miss { evicted: None });
        }
        for e in 0..4 {
            assert_eq!(s.touch(e), Touch::Hit);
        }
        assert_eq!(s.n_resident(), 4);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut s = ResidencySet::new(8, 2, EvictPolicy::Lru);
        s.touch(0);
        s.touch(1);
        s.touch(0); // 1 is now LRU
        assert_eq!(s.touch(2), Touch::Miss { evicted: Some(1) });
        assert!(s.contains(0) && s.contains(2) && !s.contains(1));
    }

    #[test]
    fn lfu_evicts_least_frequent() {
        let mut s = ResidencySet::new(8, 2, EvictPolicy::Lfu);
        s.touch(0);
        s.touch(0);
        s.touch(1); // freq: e0=2, e1=1
        assert_eq!(s.touch(2), Touch::Miss { evicted: Some(1) });
    }

    #[test]
    fn score_aware_evicts_lowest_ewma() {
        let mut s = ResidencySet::new(4, 2, EvictPolicy::ScoreAware);
        s.touch(0);
        s.touch(1);
        // expert 1 scores high, expert 0 has gone quiet
        s.note_scores(&[0.01, 0.9, 0.05, 0.04]);
        assert_eq!(s.touch(2), Touch::Miss { evicted: Some(0) });
    }

    #[test]
    fn admit_is_silent_and_evictable_first() {
        let mut s = ResidencySet::new(8, 2, EvictPolicy::Lru);
        s.touch(0);
        assert_eq!(s.admit_protecting(5, &[]), Some(None)); // paged in, no eviction
        assert_eq!(s.admit_protecting(5, &[]), None); // already resident
        assert!(s.contains(5));
        // 5 was never *touched* — it is the LRU victim, not 0
        assert_eq!(s.touch(3), Touch::Miss { evicted: Some(5) });
    }

    #[test]
    fn prefetch_wave_mates_do_not_evict_each_other() {
        let mut s = ResidencySet::new(8, 2, EvictPolicy::Lru);
        s.touch(0);
        s.touch(1); // full: {0, 1}, LRU = 0
        // one prefetch wave of two predictions onto a full cache
        assert_eq!(s.admit_protecting(6, &[]), Some(Some(0)));
        // without protection the 2nd admit would victimize recency-silent 6
        assert_eq!(s.admit_protecting(7, &[6]), Some(Some(1)));
        assert!(s.contains(6) && s.contains(7), "both predictions resident");
        // every resident protected: the admit is declined, nothing changes
        assert_eq!(s.admit_protecting(2, &[6, 7]), None);
        assert!(!s.contains(2) && s.n_resident() == 2);
    }

    #[test]
    fn unbounded_never_evicts() {
        let mut s = ResidencySet::new(4, 4, EvictPolicy::Lru);
        assert!(s.unbounded());
        for e in 0..4 {
            assert_eq!(s.touch(e), Touch::Miss { evicted: None });
        }
        for e in (0..4).rev() {
            assert_eq!(s.touch(e), Touch::Hit);
        }
        assert!(ResidencySet::new(4, 9, EvictPolicy::Lru).unbounded());
        assert!(!ResidencySet::new(4, 3, EvictPolicy::Lru).unbounded());
    }

    #[test]
    fn capacity_clamped_to_one() {
        let mut s = ResidencySet::new(4, 0, EvictPolicy::Lru);
        assert_eq!(s.capacity(), 1);
        s.touch(0);
        assert_eq!(s.touch(1), Touch::Miss { evicted: Some(0) });
    }
}
