//! The load-event ledger: every page-in, hit, and eviction, in counters
//! cheap enough to sit on the dispatch hot path.

/// Cumulative residency counters (per layer in the backend; summed for
/// the `/metrics` surface). Monotone, so a reader can snapshot before and
/// after a step and diff ([`ResidencyCounters::delta_from`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyCounters {
    /// demand accesses that found the expert loaded
    pub hits: u64,
    /// demand accesses that paged the expert in
    pub misses: u64,
    /// residents dropped to make room (resident-set churn)
    pub evictions: u64,
    /// bytes of packed panels paged in (demand misses + prefetches)
    pub bytes_paged: u64,
    /// lookahead page-ins (not counted as hits or misses)
    pub prefetches: u64,
}

impl ResidencyCounters {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit fraction of demand accesses; 0 when nothing was accessed.
    pub fn hit_rate(&self) -> f64 {
        let acc = self.accesses();
        if acc == 0 {
            0.0
        } else {
            self.hits as f64 / acc as f64
        }
    }

    pub fn add(&mut self, other: &ResidencyCounters) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.bytes_paged += other.bytes_paged;
        self.prefetches += other.prefetches;
    }

    /// Counter increments since `earlier` (a previous snapshot of the
    /// same monotone counters).
    pub fn delta_from(&self, earlier: &ResidencyCounters) -> ResidencyCounters {
        ResidencyCounters {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
            bytes_paged: self.bytes_paged - earlier.bytes_paged,
            prefetches: self.prefetches - earlier.prefetches,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_handles_empty_and_counts() {
        assert_eq!(ResidencyCounters::default().hit_rate(), 0.0);
        let c = ResidencyCounters { hits: 3, misses: 1, ..Default::default() };
        assert_eq!(c.accesses(), 4);
        assert!((c.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn add_and_delta_are_inverse() {
        let a = ResidencyCounters { hits: 5, misses: 2, evictions: 1, bytes_paged: 100, prefetches: 3 };
        let d = ResidencyCounters { hits: 2, misses: 1, evictions: 0, bytes_paged: 40, prefetches: 1 };
        let mut b = a;
        b.add(&d);
        assert_eq!(b.delta_from(&a), d);
        assert_eq!(b.hits, 7);
        assert_eq!(b.bytes_paged, 140);
    }
}
