//! Expert residency: cross-step expert-weight paging.
//!
//! The paper's cost model treats every activated expert as a fresh weight
//! fetch, which is right for a single step but wrong across steps: decode
//! traffic is temporally correlated, so the experts a batch activated at
//! step `s` are disproportionately the ones it activates at `s+1`
//! (ExpertFlow makes the same observation for offloaded serving). This
//! module models expert weights as an explicitly managed per-layer cache:
//!
//! - [`set::ResidencySet`] — which experts' packed panels are "loaded"
//!   under a capacity `C` (experts per layer), with pluggable eviction
//!   ([`set::EvictPolicy`]: LRU, LFU, or router-score-aware);
//! - [`ledger::ResidencyCounters`] — the load-event ledger (hits, misses,
//!   evictions, bytes paged, prefetch page-ins);
//! - [`prefetch::Prefetcher`] — an optional lookahead that pages in the
//!   next step's predicted-hot experts from the *previous* step's router
//!   scores, ahead of the routing decision.
//!
//! The backend consults the set in grouped dispatch (a miss packs the
//! expert's panels lazily — the simulated page-in), the routing layer can
//! bias expert selection toward residents ([`crate::moe::policy::Policy::
//! CacheAware`]), and [`crate::latency::CostModel`] charges misses a
//! page-in term so the simulated H100 latency reflects the paging tier.

pub mod ledger;
pub mod prefetch;
pub mod set;

pub use ledger::ResidencyCounters;
pub use prefetch::Prefetcher;
pub use set::{EvictPolicy, ResidencySet, Touch};

/// Residency configuration for one backend (applied to every layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResidencyConfig {
    /// Resident experts per layer. `capacity >= n_experts` is the
    /// unbounded regime: nothing is ever evicted, every miss is a
    /// compulsory first touch, and cache-aware routing bias is inert
    /// (see [`set::ResidencySet::unbounded`]).
    pub capacity: usize,
    pub evict: EvictPolicy,
    /// Lookahead page-ins per (layer, step) from the previous step's
    /// router scores; 0 disables the prefetcher.
    pub prefetch: usize,
}

impl ResidencyConfig {
    pub fn new(capacity: usize, evict: EvictPolicy, prefetch: usize) -> ResidencyConfig {
        ResidencyConfig { capacity, evict, prefetch }
    }
}

/// Aggregated residency telemetry of one backend — the `/metrics` and
/// bench JSON surface.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResidencyStats {
    /// Effective capacity (experts per layer): the bound the sets
    /// actually enforce, so `resident <= capacity * layers` always
    /// holds. Equals the configured capacity clamped to `[1, n_experts]`
    /// on a single-rank backend; under EP sharding the per-rank split
    /// rounds up (`ceil(C/R)` each, bounded by shard size), which can
    /// exceed the configured C when R does not divide it.
    pub capacity: usize,
    pub n_experts: usize,
    pub evict: EvictPolicy,
    pub prefetch: usize,
    /// counters summed over layers
    pub counters: ResidencyCounters,
    /// currently resident experts summed over layers
    pub resident: usize,
    pub layers: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_roundtrip() {
        let c = ResidencyConfig::new(8, EvictPolicy::Lru, 2);
        assert_eq!(c.capacity, 8);
        assert_eq!(c.evict, EvictPolicy::Lru);
        assert_eq!(c.prefetch, 2);
    }
}
