//! Lookahead prefetcher: decode traffic is temporally correlated, so the
//! previous step's router scores predict the next step's hot experts.
//!
//! Per layer, at each step the backend (1) applies the predictions
//! recorded at the previous step — paging those experts in *before* the
//! routing decision and expert execution, where the copy can overlap the
//! attention sub-block — and then (2) records this step's top-scoring
//! experts as the next step's predictions (fed by the model runner via
//! `Backend::residency_observe`, which aggregates router mass over the
//! rows that actually route — dead bucket rows must not steer paging).
//! Prefetched page-ins are
//! ledgered separately from demand misses: they model an async copy off
//! the critical path, so the cost model does not charge them a page-in
//! term, but bytes-paged telemetry stays honest.

/// One layer's prediction buffer.
#[derive(Debug, Clone, Default)]
pub struct Prefetcher {
    /// top-m experts to page in at the start of the next step
    pending: Vec<u16>,
    lookahead: usize,
}

impl Prefetcher {
    pub fn new(lookahead: usize) -> Prefetcher {
        Prefetcher { pending: Vec::new(), lookahead }
    }

    pub fn lookahead(&self) -> usize {
        self.lookahead
    }

    /// Drain the predictions recorded at the previous step.
    pub fn take_pending(&mut self) -> Vec<u16> {
        std::mem::take(&mut self.pending)
    }

    /// Record next-step predictions: the `lookahead` experts with the
    /// highest batch-aggregated router mass this step.
    pub fn observe(&mut self, agg_scores: &[f32]) {
        if self.lookahead == 0 {
            return;
        }
        let mut idx: Vec<u16> = (0..agg_scores.len() as u16).collect();
        // descending mass; ties by lower id (deterministic, NaN-total)
        idx.sort_by(|&a, &b| agg_scores[b as usize].total_cmp(&agg_scores[a as usize]));
        idx.truncate(self.lookahead);
        self.pending = idx;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_then_take_roundtrip() {
        let mut p = Prefetcher::new(2);
        p.observe(&[0.1, 0.9, 0.3, 0.7]);
        assert_eq!(p.take_pending(), vec![1, 3]);
        // drained: a second take is empty until the next observe
        assert!(p.take_pending().is_empty());
    }

    #[test]
    fn zero_lookahead_is_inert() {
        let mut p = Prefetcher::new(0);
        p.observe(&[0.5, 0.5]);
        assert!(p.take_pending().is_empty());
    }

    #[test]
    fn newer_observation_replaces_older() {
        let mut p = Prefetcher::new(1);
        p.observe(&[1.0, 0.0]);
        p.observe(&[0.0, 1.0]);
        assert_eq!(p.take_pending(), vec![1]);
    }
}
