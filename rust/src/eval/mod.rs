//! Quality evaluation harnesses (the paper's §4.1 + §4.2 measurements,
//! under the DESIGN.md §3 substitutions):
//!
//! - [`ce_eval`]: teacher-forced "parallel decode" over B sequences in
//!   lockstep, exactly the paper's CE methodology — routing happens within
//!   each position only. Reports CE (vs corpus tokens), CE delta and mean
//!   KL vs a vanilla reference run, and the average activated experts.
//! - [`fidelity_eval`]: greedy-generation agreement against vanilla routing
//!   (the benchmark-accuracy stand-in for Tables 1/2).

use crate::backend::Backend;
use crate::config::ModelConfig;
use crate::coordinator::sampler;
use crate::model::ModelRunner;
use crate::moe::policy::Policy;
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Per-position logits of a teacher-forced run, for reuse as reference.
pub struct ForcedRun {
    pub b: usize,
    pub positions: usize,
    pub vocab: usize,
    /// `[positions][b * vocab]`
    pub logits: Vec<Vec<f32>>,
    pub avg_t: f64,
    pub avg_load: f64,
    /// mean measured µs of the MoE stage per layer-step
    pub avg_moe_us: f64,
}

/// Run `positions` teacher-forced lockstep decode steps over `b` sequences
/// (`tokens[i]` must hold at least `positions + 1` entries).
pub fn forced_run<B: Backend>(
    runner: &ModelRunner<B>,
    tokens: &[Vec<i32>],
    positions: usize,
    policy: Policy,
    mask_padding: bool,
) -> Result<ForcedRun> {
    let b = tokens.len();
    let c = runner.cfg().clone();
    let bucket = c.bucket_for(b)?;
    assert!(positions + 1 <= c.s_max);
    for s in tokens {
        assert!(s.len() > positions, "sequences must cover all positions");
    }
    let mut batch = runner.new_batch(bucket)?;
    let mut logits = Vec::with_capacity(positions);
    let mut sum_t = 0.0;
    let mut sum_load = 0.0;
    let mut sum_us = 0.0;
    let mut n_layer_steps = 0usize;
    let mut toks = vec![0i32; bucket];
    let mut pos = vec![0i32; bucket];
    let mut live = vec![false; bucket];
    for (i, s) in tokens.iter().enumerate() {
        let _ = s;
        live[i] = true;
    }
    for t in 0..positions {
        for i in 0..b {
            toks[i] = tokens[i][t];
            pos[i] = t as i32;
        }
        let out = runner.decode_step(&mut batch, &toks, &pos, &live, policy, mask_padding)?;
        for ls in &out.layers {
            sum_t += ls.t as f64;
            sum_load += ls.load as f64;
            sum_us += ls.moe_us;
            n_layer_steps += 1;
        }
        logits.push(out.logits);
    }
    Ok(ForcedRun {
        b,
        positions,
        vocab: c.vocab,
        logits,
        avg_t: sum_t / n_layer_steps as f64,
        avg_load: sum_load / n_layer_steps as f64,
        avg_moe_us: sum_us / n_layer_steps as f64,
    })
}

/// CE metrics of a policy run against corpus tokens and a vanilla reference.
#[derive(Debug, Clone, Copy)]
pub struct CeResult {
    /// mean next-token CE against the corpus
    pub ce: f64,
    /// ce - ce_vanilla (the paper's y-axis in Figs 2/3/5-9)
    pub ce_delta: f64,
    /// mean KL(vanilla || policy) per position/sequence
    pub kl_vanilla: f64,
    /// average unique active experts per layer-step (the x-axis)
    pub avg_t: f64,
    pub avg_load: f64,
    pub avg_moe_us: f64,
}

/// Compare a policy's forced run against a vanilla reference run over the
/// same tokens. `tokens[i][positions]` supplies the CE target at the last
/// position.
pub fn ce_compare(
    tokens: &[Vec<i32>],
    policy_run: &ForcedRun,
    vanilla_run: &ForcedRun,
) -> CeResult {
    assert_eq!(policy_run.positions, vanilla_run.positions);
    assert_eq!(policy_run.b, vanilla_run.b);
    let (b, v) = (policy_run.b, policy_run.vocab);
    let mut ce = 0.0;
    let mut ce_van = 0.0;
    let mut kl = 0.0;
    let mut n = 0usize;
    for t in 0..policy_run.positions {
        for i in 0..b {
            let target = tokens[i][t + 1] as usize;
            let row_p = &policy_run.logits[t][i * v..(i + 1) * v];
            let row_v = &vanilla_run.logits[t][i * v..(i + 1) * v];
            ce += sampler::cross_entropy(row_p, target);
            ce_van += sampler::cross_entropy(row_v, target);
            kl += sampler::kl_divergence(row_v, row_p);
            n += 1;
        }
    }
    CeResult {
        ce: ce / n as f64,
        ce_delta: (ce - ce_van) / n as f64,
        kl_vanilla: kl / n as f64,
        avg_t: policy_run.avg_t,
        avg_load: policy_run.avg_load,
        avg_moe_us: policy_run.avg_moe_us,
    }
}

/// Greedy-generation fidelity vs vanilla routing: the fraction of decode
/// steps where the policy's greedy token equals vanilla's, batched like the
/// serving runs (same batch composition for both arms).
#[derive(Debug, Clone, Copy)]
pub struct FidelityResult {
    /// exact-match rate over all generated tokens
    pub token_agreement: f64,
    /// fraction of sequences whose entire continuation matches
    pub seq_exact: f64,
    pub avg_t: f64,
}

pub fn fidelity_eval<B: Backend>(
    runner: &ModelRunner<B>,
    prompts: &[Vec<i32>],
    gen_len: usize,
    policy: Policy,
) -> Result<FidelityResult> {
    let b = prompts.len();
    let c = runner.cfg().clone();
    let bucket = c.bucket_for(b)?;

    // two arms with identical start states
    let mut arms: Vec<(Policy, Vec<Vec<i32>>, f64)> = Vec::new();
    for pol in [Policy::Vanilla { k: c.top_k }, policy] {
        let mut sum_t = 0.0;
        let mut n_t = 0usize;
        let mut batch = runner.new_batch(bucket)?;
        let mut next = vec![0i32; bucket];
        let mut posv = vec![0i32; bucket];
        let mut live = vec![false; bucket];
        for (i, p) in prompts.iter().enumerate() {
            let seq = runner.prefill(p)?;
            runner.install_prefilled(&mut batch, i, &seq)?;
            next[i] = sampler::argmax(&seq.last_logits) as i32;
            posv[i] = p.len() as i32;
            live[i] = true;
        }
        let mut gen: Vec<Vec<i32>> = vec![Vec::new(); b];
        for i in 0..b {
            gen[i].push(next[i]);
        }
        for _ in 0..gen_len - 1 {
            let out = runner.decode_step(&mut batch, &next, &posv, &live, pol, true)?;
            for ls in &out.layers {
                sum_t += ls.t as f64;
                n_t += 1;
            }
            for i in 0..b {
                let row = &out.logits[i * c.vocab..(i + 1) * c.vocab];
                next[i] = sampler::argmax(row) as i32;
                posv[i] += 1;
                gen[i].push(next[i]);
            }
        }
        arms.push((pol, gen, if n_t > 0 { sum_t / n_t as f64 } else { 0.0 }));
    }

    let (_, ref_gen, _) = &arms[0];
    let (_, pol_gen, pol_avg_t) = &arms[1];
    let mut agree = 0usize;
    let mut total = 0usize;
    let mut exact = 0usize;
    for i in 0..b {
        let mut all = true;
        for t in 0..gen_len {
            total += 1;
            if ref_gen[i][t] == pol_gen[i][t] {
                agree += 1;
            } else {
                all = false;
            }
        }
        if all {
            exact += 1;
        }
    }
    Ok(FidelityResult {
        token_agreement: agree as f64 / total as f64,
        seq_exact: exact as f64 / b as f64,
        avg_t: *pol_avg_t,
    })
}

/// The four benchmark-suite slots (paper: AIME24 / GPQA / LiveCodeBench /
/// MATH_500 -> here: one synthetic-corpus domain each, DESIGN.md §3).
pub const SUITES: [(&str, &str, usize); 4] = [
    ("AIME24", "math", 1),
    ("GPQA", "qa", 3),
    ("LIVECODEBENCH", "code", 2),
    ("MATH_500", "prose", 0),
];

/// Domain-pure prompt batch for one benchmark suite (the paper's
/// "similar distribution" serving regime, §6).
pub fn suite_prompts(
    corpus: &crate::util::corpus::Corpus,
    tok: &crate::util::bpe::Tokenizer,
    rng: &mut crate::util::rng::Rng,
    domain: usize,
    b: usize,
    prompt_len: usize,
) -> Vec<Vec<i32>> {
    (0..b)
        .map(|_| {
            let text = corpus.sample_text_domain(rng, domain, prompt_len * 8);
            let mut ids: Vec<i32> =
                tok.encode(&text).iter().map(|&t| t as i32).collect();
            ids.truncate(prompt_len);
            while ids.len() < prompt_len {
                ids.push(3);
            }
            ids
        })
        .collect()
}

/// Synthetic token sequence from one domain's vocab band — the hermetic
/// stand-in for the corpus+tokenizer pipeline used by benches and CI
/// smoke runs. `CpuBackend::synthetic` gives token-id bands the same
/// domain structure, so domain-pure batches concentrate the router
/// exactly like corpus-fed ones. Tokens are mostly in-band with
/// occasional cross-domain draws (natural text is not domain-pure
/// either).
pub fn synthetic_domain_sequence(
    cfg: &ModelConfig,
    rng: &mut Rng,
    domain: usize,
    len: usize,
) -> Vec<i32> {
    let usable = cfg.vocab - 3;
    let band = (usable / cfg.n_domains).max(1);
    let lo = 3 + (domain % cfg.n_domains) * band;
    (0..len)
        .map(|_| {
            if rng.bool(0.85) {
                (lo + rng.below(band)) as i32
            } else {
                (3 + rng.below(usable)) as i32
            }
        })
        .collect()
}

/// Domain-pure synthetic prompt batch (hermetic analog of
/// [`suite_prompts`]): exactly `prompt_len` tokens each.
pub fn synthetic_domain_prompts(
    cfg: &ModelConfig,
    rng: &mut Rng,
    domain: usize,
    b: usize,
    prompt_len: usize,
) -> Vec<Vec<i32>> {
    (0..b)
        .map(|_| synthetic_domain_sequence(cfg, rng, domain, prompt_len))
        .collect()
}

/// Synthetic CE-eval batch (hermetic analog of [`sequences_from_corpus`]):
/// `len + 1` tokens per sequence so `len` teacher-forced positions all
/// have a next-token target. `mixed = true` draws each sequence from a
/// random domain; `false` uses one domain for the whole batch.
pub fn synthetic_sequences(
    cfg: &ModelConfig,
    rng: &mut Rng,
    b: usize,
    len: usize,
    mixed: bool,
) -> Vec<Vec<i32>> {
    let fixed = rng.below(cfg.n_domains);
    (0..b)
        .map(|_| {
            let d = if mixed { rng.below(cfg.n_domains) } else { fixed };
            synthetic_domain_sequence(cfg, rng, d, len + 1)
        })
        .collect()
}

/// Tokenize corpus text into fixed-length sequences for CE eval.
pub fn sequences_from_corpus(
    corpus: &crate::util::corpus::Corpus,
    tok: &crate::util::bpe::Tokenizer,
    rng: &mut crate::util::rng::Rng,
    b: usize,
    len: usize,
    mixed: bool,
) -> Vec<Vec<i32>> {
    let prompts = corpus.sample_batch(rng, b, len * 8, mixed);
    prompts
        .into_iter()
        .map(|text| {
            let mut ids: Vec<i32> = tok.encode(&text).iter().map(|&t| t as i32).collect();
            while ids.len() < len + 1 {
                ids.push(crate::util::bpe::PAD as i32 + 3);
            }
            ids.truncate(len + 1);
            ids
        })
        .collect()
}
