//! Execution backends for the decode/prefill pipeline.
//!
//! The serving stack (model runner, engine, server, benches) is written
//! against the [`Backend`] trait, which exposes the model's request-path
//! primitives at the stage level:
//!
//!   embed -> [ layer_pre -> (rust routing) -> moe_apply ] x L -> logits
//!
//! Two implementations:
//! - [`cpu::CpuBackend`] — a hermetic pure-Rust reference backend mirroring
//!   `python/compile/kernels/ref.py`. No external dependencies; builds and
//!   runs everywhere `cargo` does. This is the default and what CI tests.
//! - `pjrt::PjrtBackend` (behind the `pjrt` cargo feature) — the original
//!   PJRT/XLA runtime executing AOT HLO-text artifacts produced by
//!   `python/compile/aot.py`.
//!
//! Hidden states cross the trait boundary as host `Vec<f32>` — they are
//! `[B, d_model]`-sized (small) and the PJRT stage layout already decomposed
//! its per-layer tuple outputs through host literals, so this costs nothing
//! new. The KV cache, the only large state, stays backend-resident behind
//! the associated `Cache` type.

pub mod cpu;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::config::ModelConfig;
use crate::moe::dispatch::RoutedStep;
use crate::residency::{ResidencyCounters, ResidencyStats};
use crate::util::error::{Error, Result};

/// Output of one layer's pre-MoE work (attention sub-block + router).
pub struct LayerPre {
    /// post-attention residual stream `[B, d_model]`
    pub h: Vec<f32>,
    /// router softmax scores `[B, n_experts]`
    pub scores: Vec<f32>,
}

/// A prefilled sequence, ready to join a decode batch.
pub struct Prefilled<R> {
    /// backend-resident per-layer KV rows for the prompt
    pub rows: R,
    pub n_tokens: usize,
    /// logits after the last prompt token `[vocab]`
    pub last_logits: Vec<f32>,
}

/// A model-execution backend. One value owns the weights for one config;
/// all methods take `&self` so a backend can be shared by an engine and
/// its telemetry readers.
pub trait Backend {
    /// Per-layer KV cache state of one decode batch
    /// (logically `[L][2, bucket, S, Hkv, hd]`, K at index 0).
    type Cache;
    /// Per-layer KV rows of one prefilled sequence
    /// (logically `[L][S, Hkv, hd]` for K and V).
    type Rows;

    fn config(&self) -> &ModelConfig;

    /// Short name for logs/metrics ("cpu", "pjrt").
    fn label(&self) -> &'static str;

    /// Fresh zeroed KV cache for a `bucket`-sized decode batch.
    fn new_cache(&self, bucket: usize) -> Result<Self::Cache>;

    /// Token embedding: `tokens [B] -> hidden [B, d_model]`.
    fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Attention sub-block + router scores for layer `l` of one decode
    /// step. Writes this step's K/V at `pos` into the cache (slot-stable;
    /// padding rows use pos 0 and are masked out by routing, not here).
    fn layer_pre(
        &self,
        l: usize,
        hidden: &[f32],
        cache: &mut Self::Cache,
        pos: &[i32],
    ) -> Result<LayerPre>;

    /// MoE sub-block for layer `l`: `h + expert_ffn(rmsnorm(h), combine)`
    /// over the padded active-expert list `ids` (length = executed T
    /// bucket; padding ids carry zero combine mass).
    fn moe_apply(
        &self,
        l: usize,
        hidden: &[f32],
        combine: &[f32],
        ids: &[i32],
    ) -> Result<Vec<f32>>;

    /// MoE sub-block given the full routing artifacts of one step (the
    /// serving path). Backends that execute per-expert token groups (the
    /// CPU backend's grouped dispatch) override this to consume
    /// `step.groups` directly; the default falls back to the dense
    /// `[combine, ids]` calling convention of [`Backend::moe_apply`].
    fn moe_apply_routed(&self, l: usize, hidden: &[f32], step: &RoutedStep) -> Result<Vec<f32>> {
        self.moe_apply(l, hidden, step.combine, step.ids)
    }

    /// Final norm + unembedding: `hidden [B, d_model] -> logits [B, vocab]`.
    fn logits(&self, hidden: &[f32]) -> Result<Vec<f32>>;

    /// Prefill one prompt under vanilla routing (the paper applies OEA to
    /// decode only), returning its KV rows and last-token logits.
    fn prefill(&self, prompt: &[i32]) -> Result<Prefilled<Self::Rows>>;

    /// Install a prefilled sequence's rows into `slot` of a decode cache.
    fn install_rows(&self, cache: &mut Self::Cache, slot: usize, rows: &Self::Rows) -> Result<()>;

    /// Whether [`Backend::prefill_chunk`] is implemented — the continuous
    /// scheduler refuses to start (loudly, at engine construction) on a
    /// backend that would error at the first admission instead.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    /// Chunked prefill: run prompt tokens `tokens` (cache positions
    /// `pos0 .. pos0 + tokens.len()`) of the sequence living in `slot`
    /// directly against the decode cache, under vanilla routing (prefill
    /// is always vanilla — the paper applies OEA to decode only). Writes
    /// the chunk's K/V into `slot`'s cache rows and returns the LAST
    /// chunk token's post-stack hidden state `[d_model]` (the caller
    /// samples the first output token from it via [`Backend::logits`]
    /// once the final chunk lands). Chunks must arrive in order; per-row
    /// math must match [`Backend::prefill`] bitwise so the continuous
    /// scheduler stays equivalent to the lockstep oracle.
    fn prefill_chunk(
        &self,
        _cache: &mut Self::Cache,
        _slot: usize,
        _tokens: &[i32],
        _pos0: usize,
    ) -> Result<Vec<f32>> {
        Err(Error::Engine(
            "backend does not support chunked prefill (continuous scheduling \
             requires it; run --sched lockstep)"
                .into(),
        ))
    }

    /// Zero `slot`'s cache rows (hygiene on retirement; correctness does
    /// not depend on it because pos masks attention).
    fn clear_slot(&self, cache: &mut Self::Cache, slot: usize) -> Result<()>;

    /// Rebuild the cache at a different bucket size, moving old slot `i`
    /// to `mapping[i]` (None drops the row).
    fn repack(
        &self,
        cache: &Self::Cache,
        old_bucket: usize,
        new_bucket: usize,
        mapping: &[Option<usize>],
    ) -> Result<Self::Cache>;

    /// Expert-parallel rank shards this backend executes the MoE stage
    /// over (contiguous block sharding via [`crate::moe::ep::rank_of`]).
    /// `1` = single-rank, the default for every backend without an EP
    /// execution axis. Per-rank telemetry (`/metrics` `ep` block, per-rank
    /// `LayerStep` accounting) keys off this.
    fn ep_ranks(&self) -> usize {
        1
    }

    // ---- telemetry (optional; default = backend doesn't track it) ------

    /// Cumulative routed (nonzero-combine) token-expert assignments per
    /// expert id — the per-policy load histogram surfaced on `/metrics`
    /// and in bench JSON.
    fn expert_loads(&self) -> Option<Vec<u64>> {
        None
    }

    /// Per-expert "weights loaded" flags for layer `l`, when the backend
    /// manages a *bounded* expert residency set (the cache-aware routing
    /// view). `None` when no residency is configured or the set is
    /// unbounded — cache-aware policies then reduce to base OEA.
    fn residency_view(&self, _l: usize) -> Option<Vec<bool>> {
        None
    }

    /// Layer `l`'s cumulative residency counters (monotone; the model
    /// runner diffs them around the MoE stage to attribute per-step
    /// misses).
    fn residency_counters(&self, _l: usize) -> Option<ResidencyCounters> {
        None
    }

    /// Layer `l`'s cumulative residency counters split per EP rank
    /// (length = [`Backend::ep_ranks`]), when the backend pages each
    /// rank's expert shard independently. Monotone like
    /// [`Backend::residency_counters`]; drives per-rank miss attribution
    /// for the max-rank cost model and the `/metrics` per-rank residency
    /// block.
    fn residency_rank_counters(&self, _l: usize) -> Option<Vec<ResidencyCounters>> {
        None
    }

    /// Aggregate residency telemetry across layers (the `/metrics` and
    /// bench surface).
    fn residency_stats(&self) -> Option<ResidencyStats> {
        None
    }

    /// Whether [`Backend::residency_observe`] has a consumer (score-aware
    /// eviction or a prefetcher). The model runner skips the per-layer
    /// score aggregation entirely when this is false, keeping the decode
    /// hot path free of work nothing reads.
    fn residency_wants_scores(&self) -> bool {
        false
    }

    /// Feed one decode step's routed-row-aggregated router mass for layer
    /// `l` (per-expert sums over the rows that actually route). Drives
    /// score-aware eviction and the lookahead prefetcher; no-op for
    /// backends without a residency layer. The caller must exclude dead
    /// bucket rows — their router scores are the §6 padding garbage, and
    /// feeding them would page in experts no live token wants.
    fn residency_observe(&self, _l: usize, _agg: &[f32]) {}

    // ---- fault tolerance (optional; default = backend has no fault plane)

    /// Per-expert health flags for layer `l`, threaded into routing next
    /// to [`Backend::residency_view`]: unhealthy experts are masked out
    /// of phase-1 selection (their tokens piggyback onto healthy experts
    /// and combine weights renormalize over the surviving set). `None`
    /// when the backend has no fault-injection plane or every expert on
    /// the layer is healthy — the mask-free path must stay bitwise
    /// identical to a backend without health tracking.
    fn health_view(&self, _l: usize) -> Option<Vec<bool>> {
        None
    }

    /// Record one layer-step's degraded-routing accounting: `degraded`
    /// live tokens whose top-1 expert was health-masked (and therefore
    /// rerouted), out of `routed` live tokens routed under an active
    /// mask. No-op for backends without a fault plane.
    fn note_degraded_tokens(&self, _l: usize, _degraded: u64, _routed: u64) {}

    /// Snapshot of the backend's fault-injection plane (injected-fault
    /// counters, current health, recent degradation events) for
    /// `/metrics` and the chaos bench. `None` when no fault plan is
    /// installed.
    fn fault_stats(&self) -> Option<crate::faults::FaultStats> {
        None
    }

    /// Measured wall-clock µs each EP rank spent executing the MoE stage
    /// of the most recent grouped dispatch call (index = rank). The model
    /// runner snapshots this right after `moe_apply_routed`, landing the
    /// *measured* per-rank time in `LayerStep` next to the analytic
    /// `CostModel::step_us_ep` max-over-ranks figure. Empty for backends
    /// (or dispatch modes) that don't execute per-rank work lists.
    fn rank_wall_us(&self) -> Vec<f64> {
        Vec::new()
    }
}
