//! PJRT backend (behind the `pjrt` cargo feature): executes the AOT
//! HLO-text stage artifacts produced by `python/compile/aot.py` through
//! [`crate::runtime::Runtime`].
//!
//! Stage mapping (see `python/compile/model.py` for the frozen signatures):
//! `embed_b{b}`, `layer_pre_b{b}` + `cache_append_b{b}`, `moe_b{b}_t{t}`,
//! `logits_b{b}`, `embed_c{c}` + `prefill_layer_c{c}`, `insert_row_b{b}`.
//! Hidden states cross the trait boundary as host vectors — the stage
//! layout already decomposed per-layer tuple outputs through host literals
//! (PJRT here does not untuple), so the interchange cost is unchanged; the
//! KV cache stays device-resident inside [`PjrtKvCache`].

use std::path::Path;

use crate::backend::{Backend, LayerPre, Prefilled};
use crate::config::ModelConfig;
use crate::runtime::Runtime;
use crate::util::error::{Error, Result};

/// Device-resident per-layer combined KV caches `[2, bucket, S, Hkv, hd]`.
pub struct PjrtKvCache {
    pub bucket: usize,
    pub kvs: Vec<xla::PjRtBuffer>,
}

/// A prefilled sequence's device-side KV rows, per layer `[S, Hkv, hd]`.
pub struct PjrtKvRows {
    pub k_rows: Vec<xla::PjRtBuffer>,
    pub v_rows: Vec<xla::PjRtBuffer>,
}

pub struct PjrtBackend {
    pub rt: Runtime,
}

impl PjrtBackend {
    /// Load manifest + weights for `cfg_name` under `artifact_root`.
    pub fn load(artifact_root: &Path, cfg_name: &str) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: Runtime::load(artifact_root, cfg_name)? })
    }

    fn cache_dims(&self, bucket: usize) -> [usize; 5] {
        let c = self.rt.config();
        [2, bucket, c.s_max, c.n_kv_heads, c.head_dim]
    }
}

impl Backend for PjrtBackend {
    type Cache = PjrtKvCache;
    type Rows = PjrtKvRows;

    fn config(&self) -> &ModelConfig {
        self.rt.config()
    }

    fn label(&self) -> &'static str {
        "pjrt"
    }

    fn new_cache(&self, bucket: usize) -> Result<PjrtKvCache> {
        let c = self.config();
        let dims = self.cache_dims(bucket);
        let mut kvs = Vec::with_capacity(c.n_layers);
        for _ in 0..c.n_layers {
            kvs.push(self.rt.zeros_f32(&dims)?);
        }
        Ok(PjrtKvCache { bucket, kvs })
    }

    fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let b = tokens.len();
        let tok_buf = self.rt.upload_i32(tokens, &[b])?;
        let h = self
            .rt
            .exec1(&format!("embed_b{b}"), &[&tok_buf, self.rt.weight("embed")?])?;
        self.rt.download_f32(&h)
    }

    fn layer_pre(
        &self,
        l: usize,
        hidden: &[f32],
        cache: &mut PjrtKvCache,
        pos: &[i32],
    ) -> Result<LayerPre> {
        let c = self.config().clone();
        let b = cache.bucket;
        let p = |s: &str| format!("l{l}.{s}");
        let h_buf = self.rt.upload_f32(hidden, &[b, c.d_model])?;
        let pos_buf = self.rt.upload_i32(pos, &[b])?;
        let lits = self.rt.exec_tuple(
            &format!("layer_pre_b{b}"),
            &[
                &h_buf,
                &cache.kvs[l],
                &pos_buf,
                self.rt.weight(&p("wq"))?,
                self.rt.weight(&p("wk"))?,
                self.rt.weight(&p("wv"))?,
                self.rt.weight(&p("wo"))?,
                self.rt.weight(&p("n1"))?,
                self.rt.weight(&p("n2"))?,
                self.rt.weight(&p("router"))?,
            ],
        )?;
        let [h_lit, s_lit, k_lit, v_lit]: [xla::Literal; 4] = lits
            .try_into()
            .map_err(|_| Error::Xla("layer_pre arity".into()))?;

        // device-side cache append (single-output stage, no roundtrip)
        let kv_dims = [b, c.n_kv_heads, c.head_dim];
        let k_new = self.rt.upload_literal_f32(&k_lit, &kv_dims)?;
        let v_new = self.rt.upload_literal_f32(&v_lit, &kv_dims)?;
        cache.kvs[l] = self.rt.exec1(
            &format!("cache_append_b{b}"),
            &[&cache.kvs[l], &k_new, &v_new, &pos_buf],
        )?;

        Ok(LayerPre { h: h_lit.to_vec::<f32>()?, scores: s_lit.to_vec::<f32>()? })
    }

    fn moe_apply(
        &self,
        l: usize,
        hidden: &[f32],
        combine: &[f32],
        ids: &[i32],
    ) -> Result<Vec<f32>> {
        let c = self.config();
        let b = hidden.len() / c.d_model;
        let t_bucket = ids.len();
        let p = |s: &str| format!("l{l}.{s}");
        let h_buf = self.rt.upload_f32(hidden, &[b, c.d_model])?;
        let comb_buf = self.rt.upload_f32(combine, &[b, c.n_experts])?;
        let ids_buf = self.rt.upload_i32(ids, &[t_bucket])?;
        let out = self.rt.exec1(
            &format!("moe_b{b}_t{t_bucket}"),
            &[
                &h_buf,
                &comb_buf,
                &ids_buf,
                self.rt.weight(&p("wg"))?,
                self.rt.weight(&p("wu"))?,
                self.rt.weight(&p("wd"))?,
                self.rt.weight(&p("n2"))?,
            ],
        )?;
        self.rt.download_f32(&out)
    }

    fn logits(&self, hidden: &[f32]) -> Result<Vec<f32>> {
        let c = self.config();
        let b = hidden.len() / c.d_model;
        let h_buf = self.rt.upload_f32(hidden, &[b, c.d_model])?;
        let lg = self.rt.exec1(
            &format!("logits_b{b}"),
            &[
                &h_buf,
                self.rt.weight("final_norm")?,
                self.rt.weight("unembed")?,
            ],
        )?;
        self.rt.download_f32(&lg)
    }

    /// Chunked prefill through the `prefill_layer_c{chunk}` stages (vanilla
    /// routing in-graph, like the paper: OEA applies to decode only).
    fn prefill(&self, prompt: &[i32]) -> Result<Prefilled<PjrtKvRows>> {
        let c = self.config().clone();
        let chunk = c.prefill_chunk;
        if prompt.is_empty() {
            return Err(Error::Engine("empty prompt".into()));
        }
        if prompt.len() > c.s_max - 1 {
            return Err(Error::Engine(format!(
                "prompt of {} tokens exceeds s_max-1 = {}",
                prompt.len(),
                c.s_max - 1
            )));
        }
        let row_dims = [c.s_max, c.n_kv_heads, c.head_dim];
        let mut k_rows: Vec<xla::PjRtBuffer> = Vec::with_capacity(c.n_layers);
        let mut v_rows: Vec<xla::PjRtBuffer> = Vec::with_capacity(c.n_layers);
        for _ in 0..c.n_layers {
            k_rows.push(self.rt.zeros_f32(&row_dims)?);
            v_rows.push(self.rt.zeros_f32(&row_dims)?);
        }

        let mut last_hidden_row: Option<Vec<f32>> = None;
        let n_chunks = prompt.len().div_ceil(chunk);
        for ci in 0..n_chunks {
            let pos0 = ci * chunk;
            let mut toks = vec![0i32; chunk];
            let upto = (pos0 + chunk).min(prompt.len());
            toks[..upto - pos0].copy_from_slice(&prompt[pos0..upto]);
            let tok_buf = self.rt.upload_i32(&toks, &[chunk])?;
            let pos0_entry = self.rt.upload_i32_scalar(pos0 as i32)?;
            let pos0_buf = &pos0_entry.1;

            let mut h = self.rt.exec1(
                &format!("embed_c{chunk}"),
                &[&tok_buf, self.rt.weight("embed")?],
            )?;
            for l in 0..c.n_layers {
                let p = |s: &str| format!("l{l}.{s}");
                let lits = self.rt.exec_tuple(
                    &format!("prefill_layer_c{chunk}"),
                    &[
                        &h,
                        &k_rows[l],
                        &v_rows[l],
                        pos0_buf,
                        self.rt.weight(&p("wq"))?,
                        self.rt.weight(&p("wk"))?,
                        self.rt.weight(&p("wv"))?,
                        self.rt.weight(&p("wo"))?,
                        self.rt.weight(&p("n1"))?,
                        self.rt.weight(&p("n2"))?,
                        self.rt.weight(&p("router"))?,
                        self.rt.weight(&p("wg"))?,
                        self.rt.weight(&p("wu"))?,
                        self.rt.weight(&p("wd"))?,
                    ],
                )?;
                let [h_lit, kc_lit, vc_lit]: [xla::Literal; 3] = lits
                    .try_into()
                    .map_err(|_| Error::Xla("prefill_layer arity".into()))?;
                h = self.rt.upload_literal_f32(&h_lit, &[chunk, c.d_model])?;
                k_rows[l] = self.rt.upload_literal_f32(&kc_lit, &row_dims)?;
                v_rows[l] = self.rt.upload_literal_f32(&vc_lit, &row_dims)?;
                if ci == n_chunks - 1 && l == c.n_layers - 1 {
                    let hv = h_lit.to_vec::<f32>()?;
                    let last = (prompt.len() - 1) - pos0;
                    last_hidden_row =
                        Some(hv[last * c.d_model..(last + 1) * c.d_model].to_vec());
                }
            }
        }

        let hrow = last_hidden_row.expect("last chunk processed");
        let h1 = self.rt.upload_f32(&hrow, &[1, c.d_model])?;
        let lg_buf = self.rt.exec1(
            "logits_b1",
            &[&h1, self.rt.weight("final_norm")?, self.rt.weight("unembed")?],
        )?;
        let last_logits = self.rt.download_f32(&lg_buf)?;
        Ok(Prefilled {
            rows: PjrtKvRows { k_rows, v_rows },
            n_tokens: prompt.len(),
            last_logits,
        })
    }

    /// Fully device-side via the `insert_row` stage.
    fn install_rows(&self, cache: &mut PjrtKvCache, slot: usize, rows: &PjrtKvRows) -> Result<()> {
        let b = cache.bucket;
        if slot >= b {
            return Err(Error::Engine(format!("slot {slot} out of bucket {b}")));
        }
        let slot_entry = self.rt.upload_i32_scalar(slot as i32)?;
        let slot_buf = &slot_entry.1;
        let stage = format!("insert_row_b{b}");
        for l in 0..self.config().n_layers {
            cache.kvs[l] = self.rt.exec1(
                &stage,
                &[&cache.kvs[l], &rows.k_rows[l], &rows.v_rows[l], slot_buf],
            )?;
        }
        Ok(())
    }

    fn clear_slot(&self, cache: &mut PjrtKvCache, slot: usize) -> Result<()> {
        let c = self.config();
        let zero_row = self.rt.zeros_f32(&[c.s_max, c.n_kv_heads, c.head_dim])?;
        let slot_entry = self.rt.upload_i32_scalar(slot as i32)?;
        let slot_buf = &slot_entry.1;
        let stage = format!("insert_row_b{}", cache.bucket);
        for l in 0..c.n_layers {
            cache.kvs[l] =
                self.rt.exec1(&stage, &[&cache.kvs[l], &zero_row, &zero_row, slot_buf])?;
        }
        Ok(())
    }

    /// Host roundtrip; rare (only when the running set outgrows the
    /// current bucket).
    fn repack(
        &self,
        cache: &PjrtKvCache,
        old_bucket: usize,
        new_bucket: usize,
        mapping: &[Option<usize>],
    ) -> Result<PjrtKvCache> {
        let c = self.config();
        if cache.bucket != old_bucket || mapping.len() != old_bucket {
            return Err(Error::Engine("repack mapping/bucket mismatch".into()));
        }
        let row = c.s_max * c.n_kv_heads * c.head_dim;
        let mut out = self.new_cache(new_bucket)?;
        for l in 0..c.n_layers {
            // [2, b, S, Hkv, hd]: permute the bucket axis within each half
            let host = self.rt.download_f32(&cache.kvs[l])?;
            let mut fresh = vec![0.0f32; 2 * new_bucket * row];
            for half in 0..2 {
                let src_base = half * old_bucket * row;
                let dst_base = half * new_bucket * row;
                for (i, m) in mapping.iter().enumerate() {
                    if let Some(j) = m {
                        if *j >= new_bucket {
                            return Err(Error::Engine(format!(
                                "repack target slot {j} out of bucket {new_bucket}"
                            )));
                        }
                        fresh[dst_base + j * row..dst_base + (j + 1) * row].copy_from_slice(
                            &host[src_base + i * row..src_base + (i + 1) * row],
                        );
                    }
                }
            }
            out.kvs[l] = self.rt.upload_f32(&fresh, &self.cache_dims(new_bucket))?;
        }
        Ok(out)
    }
}
