//! Pure-Rust kernels mirroring `python/compile/kernels/ref.py` (the
//! cross-language correctness ground truth), engineered for the decode
//! hot path:
//!
//! - one cache-blocked GEMM micro-kernel ([`matmul_packed`]) behind both
//!   the dense [`matmul`] and the pre-transposed/padded expert weight
//!   layout ([`PackedMat`]) — 4 output rows per pass so each streamed
//!   weight row is reused 4×, with a branch-free autovectorizable inner
//!   loop (the old `if av == 0.0` skip pessimized dense rows and is
//!   obsolete now that zero-combine tokens are never dispatched);
//! - a fused `silu(g) · u` activation ([`silu_mul`]);
//! - `_into` variants that write caller-provided buffers, with an
//!   [`Arena`] supplying scratch so the hot loop performs no per-call
//!   heap allocation;
//! - the token-grouped expert FFN ([`moe_ffn_groups`]) executing an
//!   [`ExpertGroups`] work-list, and the original gather-style kernel
//!   ([`moe_ffn_gather`]) kept as the correctness oracle.
//!
//! All math is f32; golden fixtures in `rust/tests/cpu_backend_golden.rs`
//! pin these against the JAX oracles. Per-row results are independent of
//! batch composition (each output element accumulates over `k` in the
//! same order regardless of how rows are grouped or chunked), which is
//! what makes serial grouped dispatch bitwise-identical to the gather
//! oracle's per-token math; the threaded partial-accumulator reduce in
//! the backend adds only rounding-level (~ulp) reassociation.

use crate::moe::dispatch::ExpertGroups;
use crate::util::arena::Arena;

/// Pad width of packed weight columns (f32 lanes of one AVX2 register;
/// also divides every preset's `d_model`/`d_expert`, so padding is
/// usually a no-op).
pub const LANES: usize = 8;

/// Which kernel implementations the hot path runs. `Scalar` is the
/// golden oracle — every bitwise pin in the test suite is stated
/// against it, exactly the way gather dispatch backs grouped dispatch.
/// `Simd` selects the explicit AVX2+FMA kernels when the CPU supports
/// them (checked once per call via [`simd_available`], falling back to
/// scalar otherwise), equivalence-tested to ≤1e-4 but never bitwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelMode {
    /// portable scalar loops (default; the correctness oracle)
    #[default]
    Scalar,
    /// runtime-dispatched AVX2+FMA wide-lane kernels
    Simd,
}

impl KernelMode {
    pub fn label(self) -> &'static str {
        match self {
            KernelMode::Scalar => "scalar",
            KernelMode::Simd => "simd",
        }
    }
}

/// True when the explicit SIMD kernels can run on this CPU (x86-64 with
/// AVX2 and FMA). `KernelMode::Simd` degrades to scalar when false, so
/// requesting SIMD is always safe.
#[cfg(target_arch = "x86_64")]
pub fn simd_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// True when the explicit SIMD kernels can run on this CPU.
#[cfg(not(target_arch = "x86_64"))]
pub fn simd_available() -> bool {
    false
}

#[inline]
fn simd_on(mode: KernelMode) -> bool {
    mode == KernelMode::Simd && simd_available()
}

/// Storage dtype of a packed expert panel. Decode is memory-bound, so
/// panel bytes are the latency currency: bf16 halves them at ~2^-8
/// relative rounding, int8 (per-packed-row scale) cuts them ~4× at a
/// quality delta the eval harness measures rather than assumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PanelDtype {
    /// full precision (default; all bitwise pins hold)
    #[default]
    F32,
    /// truncated-mantissa f32 (round-to-nearest-even high 16 bits)
    Bf16,
    /// symmetric int8 with one f32 scale per packed `[n_pad]` row
    Int8,
}

impl PanelDtype {
    pub fn label(self) -> &'static str {
        match self {
            PanelDtype::F32 => "f32",
            PanelDtype::Bf16 => "bf16",
            PanelDtype::Int8 => "int8",
        }
    }
}

/// Round-to-nearest-even truncation of an f32 to bf16 bits.
#[inline]
pub fn bf16_from_f32(x: f32) -> u16 {
    let b = x.to_bits();
    (b.wrapping_add(0x7fff + ((b >> 16) & 1)) >> 16) as u16
}

/// Widen bf16 bits back to f32 (exact).
#[inline]
pub fn bf16_to_f32(u: u16) -> f32 {
    f32::from_bits((u as u32) << 16)
}

/// Dtype-tagged panel storage behind [`PackedMat`]. Int8 keeps one f32
/// scale per packed row (`experts * k` scales), chosen as
/// `max_abs(row) / 127` so dequant is a single multiply fused into the
/// GEMM coefficient.
#[derive(Debug, Clone)]
enum PanelData {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    I8 { q: Vec<i8>, scale: Vec<f32> },
}

/// A borrowed view of one expert's `[k, n_pad]` panel in its storage
/// dtype; what the dtype-dispatched GEMM ([`matmul_view`]) consumes.
#[derive(Debug, Clone, Copy)]
pub enum PanelView<'a> {
    F32(&'a [f32]),
    Bf16(&'a [u16]),
    I8 { q: &'a [i8], scale: &'a [f32] },
}

impl PanelView<'_> {
    /// Element count of the viewed panel (`k * n_pad`).
    pub fn len(&self) -> usize {
        match self {
            PanelView::F32(p) => p.len(),
            PanelView::Bf16(p) => p.len(),
            PanelView::I8 { q, .. } => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A weight matrix (or a bank of per-expert matrices) pre-packed for
/// [`matmul_packed`]: row-major `[K, n_pad]` panels with `n_pad` the
/// column count rounded up to [`LANES`] and the padding columns zeroed.
/// The `[K, N]` orientation means the GEMM inner loop streams weight rows
/// contiguously (the layout `ref.py` already uses), and the padding keeps
/// every row a whole number of vector lanes. Panels may be stored
/// quantized ([`PanelDtype`]); quantization happens once at pack time
/// and dequant is fused into the micro-kernel.
#[derive(Debug, Clone)]
pub struct PackedMat {
    /// reduction dimension (rows of one panel)
    pub k: usize,
    /// logical output columns
    pub n: usize,
    /// padded output columns (row stride)
    pub n_pad: usize,
    /// number of stacked per-expert panels
    pub experts: usize,
    data: PanelData,
}

impl PackedMat {
    /// Pack `experts` stacked `[k, n]` row-major matrices at f32.
    pub fn pack(raw: &[f32], experts: usize, k: usize, n: usize) -> PackedMat {
        Self::pack_dtype(raw, experts, k, n, PanelDtype::F32)
    }

    /// Pack `experts` stacked `[k, n]` row-major matrices, quantizing to
    /// `dtype` at pack time.
    pub fn pack_dtype(
        raw: &[f32],
        experts: usize,
        k: usize,
        n: usize,
        dtype: PanelDtype,
    ) -> PackedMat {
        debug_assert_eq!(raw.len(), experts * k * n);
        let n_pad = n.div_ceil(LANES) * LANES;
        let rows = experts * k;
        let mut padded = vec![0.0f32; rows * n_pad];
        for row in 0..rows {
            padded[row * n_pad..row * n_pad + n].copy_from_slice(&raw[row * n..(row + 1) * n]);
        }
        let data = match dtype {
            PanelDtype::F32 => PanelData::F32(padded),
            PanelDtype::Bf16 => {
                PanelData::Bf16(padded.iter().map(|&x| bf16_from_f32(x)).collect())
            }
            PanelDtype::Int8 => {
                let mut q = vec![0i8; rows * n_pad];
                let mut scale = vec![0.0f32; rows];
                for row in 0..rows {
                    let src = &padded[row * n_pad..(row + 1) * n_pad];
                    let amax = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                    if amax > 0.0 {
                        let s = amax / 127.0;
                        scale[row] = s;
                        let inv = 1.0 / s;
                        for (dst, &x) in q[row * n_pad..(row + 1) * n_pad].iter_mut().zip(src) {
                            *dst = (x * inv).round().clamp(-127.0, 127.0) as i8;
                        }
                    }
                }
                PanelData::I8 { q, scale }
            }
        };
        PackedMat { k, n, n_pad, experts, data }
    }

    /// Storage dtype of the panels.
    pub fn dtype(&self) -> PanelDtype {
        match &self.data {
            PanelData::F32(_) => PanelDtype::F32,
            PanelData::Bf16(_) => PanelDtype::Bf16,
            PanelData::I8 { .. } => PanelDtype::Int8,
        }
    }

    /// Bytes actually resident for the packed bank — the number the
    /// residency plane charges per page-in, so it must track the
    /// storage dtype, not a hard-coded f32.
    pub fn bytes(&self) -> usize {
        let elems = self.experts * self.k * self.n_pad;
        match &self.data {
            PanelData::F32(_) => elems * std::mem::size_of::<f32>(),
            PanelData::Bf16(_) => elems * std::mem::size_of::<u16>(),
            PanelData::I8 { .. } => {
                elems + self.experts * self.k * std::mem::size_of::<f32>()
            }
        }
    }

    /// Expert `e`'s `[k, n_pad]` panel as f32. Panics for quantized
    /// panels — quantized consumers go through [`PackedMat::expert_view`].
    #[inline]
    pub fn expert(&self, e: usize) -> &[f32] {
        let stride = self.k * self.n_pad;
        match &self.data {
            PanelData::F32(d) => &d[e * stride..(e + 1) * stride],
            _ => panic!(
                "PackedMat::expert is f32-only (panel dtype is {}); use expert_view",
                self.dtype().label()
            ),
        }
    }

    /// Expert `e`'s `[k, n_pad]` panel in its storage dtype.
    #[inline]
    pub fn expert_view(&self, e: usize) -> PanelView<'_> {
        let stride = self.k * self.n_pad;
        match &self.data {
            PanelData::F32(d) => PanelView::F32(&d[e * stride..(e + 1) * stride]),
            PanelData::Bf16(d) => PanelView::Bf16(&d[e * stride..(e + 1) * stride]),
            PanelData::I8 { q, scale } => PanelView::I8 {
                q: &q[e * stride..(e + 1) * stride],
                scale: &scale[e * self.k..(e + 1) * self.k],
            },
        }
    }
}

/// Core GEMM micro-kernel: `out[m, n_pad] = a[m, k] @ panel[k, n_pad]`,
/// where `a` rows are `lda` elements apart (so callers can feed padded
/// scratch rows straight back in as the next GEMM's input). `out` is
/// overwritten. Processes 4 output rows per pass — the panel row loaded
/// in the inner loop is reused for all 4, and the 4-way accumulate over
/// a full vector row autovectorizes without branches. Output rows stay
/// L1-resident across the `k` sweep, which is the cache-blocking that
/// matters at decode shapes (`m <= B`, panel streamed once per 4 rows).
pub fn matmul_packed(
    a: &[f32],
    lda: usize,
    panel: &[f32],
    k: usize,
    n_pad: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert!(m == 0 || a.len() >= (m - 1) * lda + k);
    debug_assert_eq!(panel.len(), k * n_pad);
    debug_assert_eq!(out.len(), m * n_pad);
    out.fill(0.0);
    let mut i = 0;
    while i + 4 <= m {
        let block = &mut out[i * n_pad..(i + 4) * n_pad];
        let (o0, rest) = block.split_at_mut(n_pad);
        let (o1, rest) = rest.split_at_mut(n_pad);
        let (o2, o3) = rest.split_at_mut(n_pad);
        let a0 = &a[i * lda..i * lda + k];
        let a1 = &a[(i + 1) * lda..(i + 1) * lda + k];
        let a2 = &a[(i + 2) * lda..(i + 2) * lda + k];
        let a3 = &a[(i + 3) * lda..(i + 3) * lda + k];
        for kk in 0..k {
            let brow = &panel[kk * n_pad..(kk + 1) * n_pad];
            let (c0, c1, c2, c3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
            let it = o0
                .iter_mut()
                .zip(o1.iter_mut())
                .zip(o2.iter_mut())
                .zip(o3.iter_mut())
                .zip(brow.iter());
            for ((((v0, v1), v2), v3), &bv) in it {
                *v0 += c0 * bv;
                *v1 += c1 * bv;
                *v2 += c2 * bv;
                *v3 += c3 * bv;
            }
        }
        i += 4;
    }
    while i < m {
        let orow = &mut out[i * n_pad..(i + 1) * n_pad];
        let arow = &a[i * lda..i * lda + k];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &panel[kk * n_pad..(kk + 1) * n_pad];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
        i += 1;
    }
}

/// `out[m, n] = a[m, k] @ b[k, n]` (row-major) into a caller buffer.
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    // a dense [K, N] matrix is a packed panel with n_pad = n
    matmul_packed(a, k, b, k, n, m, out);
}

/// Allocating wrapper over [`matmul_into`].
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_into(a, b, m, k, n, &mut out);
    out
}

/// Scalar GEMM over a bf16-stored panel: widen each weight element to
/// f32 in the inner loop (exact — bf16 is a truncated f32). The scalar
/// oracle for the AVX2 bf16 kernel.
pub fn matmul_packed_bf16(
    a: &[f32],
    lda: usize,
    panel: &[u16],
    k: usize,
    n_pad: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert!(m == 0 || a.len() >= (m - 1) * lda + k);
    debug_assert_eq!(panel.len(), k * n_pad);
    debug_assert_eq!(out.len(), m * n_pad);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * lda..i * lda + k];
        let orow = &mut out[i * n_pad..(i + 1) * n_pad];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &panel[kk * n_pad..(kk + 1) * n_pad];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bf16_to_f32(bv);
            }
        }
    }
}

/// Scalar GEMM over an int8-stored panel with one f32 `scale` per packed
/// row: dequant is fused into the coefficient (`c = a[kk] * scale[kk]`),
/// so the inner loop is one int→float convert and one FMA per element.
/// The scalar oracle for the AVX2 int8 kernel.
pub fn matmul_packed_i8(
    a: &[f32],
    lda: usize,
    q: &[i8],
    scale: &[f32],
    k: usize,
    n_pad: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert!(m == 0 || a.len() >= (m - 1) * lda + k);
    debug_assert_eq!(q.len(), k * n_pad);
    debug_assert_eq!(scale.len(), k);
    debug_assert_eq!(out.len(), m * n_pad);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * lda..i * lda + k];
        let orow = &mut out[i * n_pad..(i + 1) * n_pad];
        for (kk, &av) in arow.iter().enumerate() {
            let c = av * scale[kk];
            if c == 0.0 {
                continue;
            }
            let brow = &q[kk * n_pad..(kk + 1) * n_pad];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += c * bv as f32;
            }
        }
    }
}

/// Mode-dispatched f32 GEMM: the AVX2 micro-kernel when `mode` asks for
/// SIMD, the CPU supports it, and the panel stride is lane-aligned
/// (packed panels always are; dense callers with odd `n_pad` fall back
/// to scalar). Results match scalar to ≤1e-4, never bitwise.
pub fn matmul_packed_mode(
    a: &[f32],
    lda: usize,
    panel: &[f32],
    k: usize,
    n_pad: usize,
    m: usize,
    out: &mut [f32],
    mode: KernelMode,
) {
    if simd_on(mode) && n_pad % LANES == 0 {
        #[cfg(target_arch = "x86_64")]
        {
            debug_assert!(m == 0 || a.len() >= (m - 1) * lda + k);
            debug_assert_eq!(panel.len(), k * n_pad);
            debug_assert_eq!(out.len(), m * n_pad);
            unsafe { simd::matmul_f32(a, lda, panel, k, n_pad, m, out) };
            return;
        }
    }
    matmul_packed(a, lda, panel, k, n_pad, m, out);
}

/// Dtype- and mode-dispatched GEMM over one expert panel view; the
/// single entry point the grouped-dispatch hot path uses.
pub fn matmul_view(
    a: &[f32],
    lda: usize,
    panel: PanelView<'_>,
    k: usize,
    n_pad: usize,
    m: usize,
    out: &mut [f32],
    mode: KernelMode,
) {
    match panel {
        PanelView::F32(p) => matmul_packed_mode(a, lda, p, k, n_pad, m, out, mode),
        PanelView::Bf16(p) => {
            if simd_on(mode) && n_pad % LANES == 0 {
                #[cfg(target_arch = "x86_64")]
                {
                    debug_assert!(m == 0 || a.len() >= (m - 1) * lda + k);
                    debug_assert_eq!(p.len(), k * n_pad);
                    debug_assert_eq!(out.len(), m * n_pad);
                    unsafe { simd::matmul_bf16(a, lda, p, k, n_pad, m, out) };
                    return;
                }
            }
            matmul_packed_bf16(a, lda, p, k, n_pad, m, out);
        }
        PanelView::I8 { q, scale } => {
            if simd_on(mode) && n_pad % LANES == 0 {
                #[cfg(target_arch = "x86_64")]
                {
                    debug_assert!(m == 0 || a.len() >= (m - 1) * lda + k);
                    debug_assert_eq!(q.len(), k * n_pad);
                    debug_assert_eq!(scale.len(), k);
                    debug_assert_eq!(out.len(), m * n_pad);
                    unsafe { simd::matmul_i8(a, lda, q, scale, k, n_pad, m, out) };
                    return;
                }
            }
            matmul_packed_i8(a, lda, q, scale, k, n_pad, m, out);
        }
    }
}

/// Explicit AVX2+FMA kernels. Every `unsafe fn` here is sound only on a
/// CPU with AVX2 and FMA; callers gate on [`simd_available`] (and
/// lane-aligned strides for the GEMMs). The vectorized `exp` is the
/// classic Cephes-style degree-5 polynomial with two-step ln2 range
/// reduction — ~1e-7 relative error, far inside the ≤1e-4 equivalence
/// budget the tests enforce.
#[cfg(target_arch = "x86_64")]
mod simd {
    use core::arch::x86_64::*;

    /// Horizontal sum of 8 lanes.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// Horizontal max of 8 lanes.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hmax(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_max_ps(lo, hi);
        let s = _mm_max_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_max_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// Vectorized `exp(x)` (Cephes polynomial, inputs clamped to the
    /// finite-f32 exponent range).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn exp_ps(x: __m256) -> __m256 {
        let x = _mm256_min_ps(x, _mm256_set1_ps(88.376_26));
        let x = _mm256_max_ps(x, _mm256_set1_ps(-88.376_26));
        // n = round(x * log2(e)) via floor(x * log2e + 0.5)
        let fx = _mm256_fmadd_ps(
            x,
            _mm256_set1_ps(std::f32::consts::LOG2_E),
            _mm256_set1_ps(0.5),
        );
        let fx = _mm256_floor_ps(fx);
        // r = x - n*ln2 in two steps for extra precision
        let mut r = _mm256_fnmadd_ps(fx, _mm256_set1_ps(0.693_359_4), x);
        r = _mm256_fnmadd_ps(fx, _mm256_set1_ps(-2.121_944_4e-4), r);
        let r2 = _mm256_mul_ps(r, r);
        let mut y = _mm256_set1_ps(1.987_569_1e-4);
        y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(1.398_199_9e-3));
        y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(8.333_452e-3));
        y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(4.166_579_6e-2));
        y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(1.666_666_5e-1));
        y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(5.000_000_3e-1));
        y = _mm256_fmadd_ps(y, r2, r);
        y = _mm256_add_ps(y, _mm256_set1_ps(1.0));
        // scale by 2^n through the exponent bits
        let n = _mm256_cvttps_epi32(fx);
        let pow2n = _mm256_castsi256_ps(_mm256_slli_epi32(
            _mm256_add_epi32(n, _mm256_set1_epi32(0x7f)),
            23,
        ));
        _mm256_mul_ps(y, pow2n)
    }

    /// Widen 8 bf16 values to an f32 vector (exact).
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn load_bf16(p: *const u16) -> __m256 {
        let h = _mm_loadu_si128(p as *const __m128i);
        _mm256_castsi256_ps(_mm256_slli_epi32(_mm256_cvtepu16_epi32(h), 16))
    }

    /// Widen 8 int8 values to an f32 vector.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn load_i8(p: *const i8) -> __m256 {
        let b = _mm_loadl_epi64(p as *const __m128i);
        _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(b))
    }

    /// AVX2 f32 GEMM: 4 output rows × 16 columns of register blocking
    /// (8 ymm accumulators), each streamed panel row reused 4×, FMA
    /// throughput-bound at decode shapes. Requires `n_pad % 8 == 0`.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn matmul_f32(
        a: &[f32],
        lda: usize,
        panel: &[f32],
        k: usize,
        n_pad: usize,
        m: usize,
        out: &mut [f32],
    ) {
        out.fill(0.0);
        let ap = a.as_ptr();
        let pp = panel.as_ptr();
        let op = out.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= m {
            let a0 = ap.add(i * lda);
            let a1 = ap.add((i + 1) * lda);
            let a2 = ap.add((i + 2) * lda);
            let a3 = ap.add((i + 3) * lda);
            let o0 = op.add(i * n_pad);
            let o1 = op.add((i + 1) * n_pad);
            let o2 = op.add((i + 2) * n_pad);
            let o3 = op.add((i + 3) * n_pad);
            let mut j = 0;
            while j + 16 <= n_pad {
                let mut c00 = _mm256_setzero_ps();
                let mut c01 = _mm256_setzero_ps();
                let mut c10 = _mm256_setzero_ps();
                let mut c11 = _mm256_setzero_ps();
                let mut c20 = _mm256_setzero_ps();
                let mut c21 = _mm256_setzero_ps();
                let mut c30 = _mm256_setzero_ps();
                let mut c31 = _mm256_setzero_ps();
                for kk in 0..k {
                    let bp = pp.add(kk * n_pad + j);
                    let b0 = _mm256_loadu_ps(bp);
                    let b1 = _mm256_loadu_ps(bp.add(8));
                    let v0 = _mm256_set1_ps(*a0.add(kk));
                    c00 = _mm256_fmadd_ps(v0, b0, c00);
                    c01 = _mm256_fmadd_ps(v0, b1, c01);
                    let v1 = _mm256_set1_ps(*a1.add(kk));
                    c10 = _mm256_fmadd_ps(v1, b0, c10);
                    c11 = _mm256_fmadd_ps(v1, b1, c11);
                    let v2 = _mm256_set1_ps(*a2.add(kk));
                    c20 = _mm256_fmadd_ps(v2, b0, c20);
                    c21 = _mm256_fmadd_ps(v2, b1, c21);
                    let v3 = _mm256_set1_ps(*a3.add(kk));
                    c30 = _mm256_fmadd_ps(v3, b0, c30);
                    c31 = _mm256_fmadd_ps(v3, b1, c31);
                }
                _mm256_storeu_ps(o0.add(j), c00);
                _mm256_storeu_ps(o0.add(j + 8), c01);
                _mm256_storeu_ps(o1.add(j), c10);
                _mm256_storeu_ps(o1.add(j + 8), c11);
                _mm256_storeu_ps(o2.add(j), c20);
                _mm256_storeu_ps(o2.add(j + 8), c21);
                _mm256_storeu_ps(o3.add(j), c30);
                _mm256_storeu_ps(o3.add(j + 8), c31);
                j += 16;
            }
            while j < n_pad {
                let mut c0 = _mm256_setzero_ps();
                let mut c1 = _mm256_setzero_ps();
                let mut c2 = _mm256_setzero_ps();
                let mut c3 = _mm256_setzero_ps();
                for kk in 0..k {
                    let b0 = _mm256_loadu_ps(pp.add(kk * n_pad + j));
                    c0 = _mm256_fmadd_ps(_mm256_set1_ps(*a0.add(kk)), b0, c0);
                    c1 = _mm256_fmadd_ps(_mm256_set1_ps(*a1.add(kk)), b0, c1);
                    c2 = _mm256_fmadd_ps(_mm256_set1_ps(*a2.add(kk)), b0, c2);
                    c3 = _mm256_fmadd_ps(_mm256_set1_ps(*a3.add(kk)), b0, c3);
                }
                _mm256_storeu_ps(o0.add(j), c0);
                _mm256_storeu_ps(o1.add(j), c1);
                _mm256_storeu_ps(o2.add(j), c2);
                _mm256_storeu_ps(o3.add(j), c3);
                j += 8;
            }
            i += 4;
        }
        while i < m {
            let arow = ap.add(i * lda);
            let orow = op.add(i * n_pad);
            let mut j = 0;
            while j < n_pad {
                let mut c0 = _mm256_setzero_ps();
                for kk in 0..k {
                    let b0 = _mm256_loadu_ps(pp.add(kk * n_pad + j));
                    c0 = _mm256_fmadd_ps(_mm256_set1_ps(*arow.add(kk)), b0, c0);
                }
                _mm256_storeu_ps(orow.add(j), c0);
                j += 8;
            }
            i += 1;
        }
    }

    /// AVX2 bf16 GEMM: widen 8 weights per load, then the same FMA
    /// pattern as the f32 kernel (4 rows × 8 columns).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn matmul_bf16(
        a: &[f32],
        lda: usize,
        panel: &[u16],
        k: usize,
        n_pad: usize,
        m: usize,
        out: &mut [f32],
    ) {
        out.fill(0.0);
        let ap = a.as_ptr();
        let pp = panel.as_ptr();
        let op = out.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= m {
            let a0 = ap.add(i * lda);
            let a1 = ap.add((i + 1) * lda);
            let a2 = ap.add((i + 2) * lda);
            let a3 = ap.add((i + 3) * lda);
            let mut j = 0;
            while j < n_pad {
                let mut c0 = _mm256_setzero_ps();
                let mut c1 = _mm256_setzero_ps();
                let mut c2 = _mm256_setzero_ps();
                let mut c3 = _mm256_setzero_ps();
                for kk in 0..k {
                    let b0 = load_bf16(pp.add(kk * n_pad + j));
                    c0 = _mm256_fmadd_ps(_mm256_set1_ps(*a0.add(kk)), b0, c0);
                    c1 = _mm256_fmadd_ps(_mm256_set1_ps(*a1.add(kk)), b0, c1);
                    c2 = _mm256_fmadd_ps(_mm256_set1_ps(*a2.add(kk)), b0, c2);
                    c3 = _mm256_fmadd_ps(_mm256_set1_ps(*a3.add(kk)), b0, c3);
                }
                _mm256_storeu_ps(op.add(i * n_pad + j), c0);
                _mm256_storeu_ps(op.add((i + 1) * n_pad + j), c1);
                _mm256_storeu_ps(op.add((i + 2) * n_pad + j), c2);
                _mm256_storeu_ps(op.add((i + 3) * n_pad + j), c3);
                j += 8;
            }
            i += 4;
        }
        while i < m {
            let arow = ap.add(i * lda);
            let mut j = 0;
            while j < n_pad {
                let mut c0 = _mm256_setzero_ps();
                for kk in 0..k {
                    let b0 = load_bf16(pp.add(kk * n_pad + j));
                    c0 = _mm256_fmadd_ps(_mm256_set1_ps(*arow.add(kk)), b0, c0);
                }
                _mm256_storeu_ps(op.add(i * n_pad + j), c0);
                j += 8;
            }
            i += 1;
        }
    }

    /// AVX2 int8 GEMM with fused per-row dequant: the broadcast
    /// coefficient is `a[kk] * scale[kk]`, so the inner loop is one
    /// sign-extend + convert + FMA per 8 weights (4 rows × 8 columns).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn matmul_i8(
        a: &[f32],
        lda: usize,
        q: &[i8],
        scale: &[f32],
        k: usize,
        n_pad: usize,
        m: usize,
        out: &mut [f32],
    ) {
        out.fill(0.0);
        let ap = a.as_ptr();
        let qp = q.as_ptr();
        let op = out.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= m {
            let a0 = ap.add(i * lda);
            let a1 = ap.add((i + 1) * lda);
            let a2 = ap.add((i + 2) * lda);
            let a3 = ap.add((i + 3) * lda);
            let mut j = 0;
            while j < n_pad {
                let mut c0 = _mm256_setzero_ps();
                let mut c1 = _mm256_setzero_ps();
                let mut c2 = _mm256_setzero_ps();
                let mut c3 = _mm256_setzero_ps();
                for (kk, &s) in scale.iter().enumerate().take(k) {
                    let b0 = load_i8(qp.add(kk * n_pad + j));
                    c0 = _mm256_fmadd_ps(_mm256_set1_ps(*a0.add(kk) * s), b0, c0);
                    c1 = _mm256_fmadd_ps(_mm256_set1_ps(*a1.add(kk) * s), b0, c1);
                    c2 = _mm256_fmadd_ps(_mm256_set1_ps(*a2.add(kk) * s), b0, c2);
                    c3 = _mm256_fmadd_ps(_mm256_set1_ps(*a3.add(kk) * s), b0, c3);
                }
                _mm256_storeu_ps(op.add(i * n_pad + j), c0);
                _mm256_storeu_ps(op.add((i + 1) * n_pad + j), c1);
                _mm256_storeu_ps(op.add((i + 2) * n_pad + j), c2);
                _mm256_storeu_ps(op.add((i + 3) * n_pad + j), c3);
                j += 8;
            }
            i += 4;
        }
        while i < m {
            let arow = ap.add(i * lda);
            let mut j = 0;
            while j < n_pad {
                let mut c0 = _mm256_setzero_ps();
                for (kk, &s) in scale.iter().enumerate().take(k) {
                    let b0 = load_i8(qp.add(kk * n_pad + j));
                    c0 = _mm256_fmadd_ps(_mm256_set1_ps(*arow.add(kk) * s), b0, c0);
                }
                _mm256_storeu_ps(op.add(i * n_pad + j), c0);
                j += 8;
            }
            i += 1;
        }
    }

    /// Vectorized fused SwiGLU: `g = silu(g) * u` with the Cephes exp;
    /// scalar (libm) tail for the last `len % 8` elements.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn silu_mul(g: &mut [f32], u: &[f32]) {
        let n = g.len();
        let gp = g.as_mut_ptr();
        let up = u.as_ptr();
        let one = _mm256_set1_ps(1.0);
        let mut i = 0;
        while i + 8 <= n {
            let gv = _mm256_loadu_ps(gp.add(i));
            let uv = _mm256_loadu_ps(up.add(i));
            let e = exp_ps(_mm256_sub_ps(_mm256_setzero_ps(), gv));
            let s = _mm256_div_ps(gv, _mm256_add_ps(one, e));
            _mm256_storeu_ps(gp.add(i), _mm256_mul_ps(s, uv));
            i += 8;
        }
        for j in i..n {
            let x = *gp.add(j);
            *gp.add(j) = x / (1.0 + (-x).exp()) * *up.add(j);
        }
    }

    /// Vectorized RMSNorm: FMA sum-of-squares reduction, then one
    /// multiply pass; scalar tails for `d % 8`.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn rmsnorm_into(h: &[f32], scale: &[f32], d: usize, eps: f32, out: &mut [f32]) {
        let rows = h.len() / d;
        let sp = scale.as_ptr();
        for r in 0..rows {
            let row = h.as_ptr().add(r * d);
            let orow = out.as_mut_ptr().add(r * d);
            let mut acc = _mm256_setzero_ps();
            let mut i = 0;
            while i + 8 <= d {
                let v = _mm256_loadu_ps(row.add(i));
                acc = _mm256_fmadd_ps(v, v, acc);
                i += 8;
            }
            let mut ms = hsum(acc);
            for j in i..d {
                let x = *row.add(j);
                ms += x * x;
            }
            ms /= d as f32;
            let inv = 1.0 / (ms + eps).sqrt();
            let vinv = _mm256_set1_ps(inv);
            i = 0;
            while i + 8 <= d {
                let v = _mm256_loadu_ps(row.add(i));
                let s = _mm256_loadu_ps(sp.add(i));
                _mm256_storeu_ps(orow.add(i), _mm256_mul_ps(_mm256_mul_ps(v, vinv), s));
                i += 8;
            }
            for j in i..d {
                *orow.add(j) = *row.add(j) * inv * *sp.add(j);
            }
        }
    }

    /// Vectorized numerically-stable softmax per row: max, Cephes exp +
    /// running sum, then scale by the reciprocal; scalar tails for
    /// `n % 8`.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn softmax_rows(x: &mut [f32], n: usize) {
        for row in x.chunks_exact_mut(n) {
            let rp = row.as_mut_ptr();
            let mut vmax = _mm256_set1_ps(f32::NEG_INFINITY);
            let mut i = 0;
            while i + 8 <= n {
                vmax = _mm256_max_ps(vmax, _mm256_loadu_ps(rp.add(i)));
                i += 8;
            }
            let mut m = hmax(vmax);
            for j in i..n {
                m = m.max(*rp.add(j));
            }
            let vm = _mm256_set1_ps(m);
            let mut vsum = _mm256_setzero_ps();
            i = 0;
            while i + 8 <= n {
                let e = exp_ps(_mm256_sub_ps(_mm256_loadu_ps(rp.add(i)), vm));
                _mm256_storeu_ps(rp.add(i), e);
                vsum = _mm256_add_ps(vsum, e);
                i += 8;
            }
            let mut z = hsum(vsum);
            for j in i..n {
                let e = (*rp.add(j) - m).exp();
                *rp.add(j) = e;
                z += e;
            }
            let vz = _mm256_set1_ps(1.0 / z);
            i = 0;
            while i + 8 <= n {
                _mm256_storeu_ps(rp.add(i), _mm256_mul_ps(_mm256_loadu_ps(rp.add(i)), vz));
                i += 8;
            }
            for j in i..n {
                *rp.add(j) /= z;
            }
        }
    }
}

/// RMSNorm per row into a caller buffer: `h / sqrt(mean(h^2) + eps) *
/// scale` (ref.rmsnorm_ref).
pub fn rmsnorm_into(h: &[f32], scale: &[f32], d: usize, eps: f32, out: &mut [f32]) {
    debug_assert_eq!(h.len() % d, 0);
    debug_assert_eq!(scale.len(), d);
    debug_assert_eq!(out.len(), h.len());
    for (row, orow) in h.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms: f32 = row.iter().map(|&x| x * x).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for ((o, &x), &s) in orow.iter_mut().zip(row.iter()).zip(scale.iter()) {
            *o = x * inv * s;
        }
    }
}

/// Allocating wrapper over [`rmsnorm_into`].
pub fn rmsnorm(h: &[f32], scale: &[f32], d: usize, eps: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; h.len()];
    rmsnorm_into(h, scale, d, eps, &mut out);
    out
}

/// Numerically-stable softmax over each row of `x [rows, n]`, in place.
pub fn softmax_rows(x: &mut [f32], n: usize) {
    debug_assert_eq!(x.len() % n, 0);
    for row in x.chunks_exact_mut(n) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Fused SwiGLU activation: `g[i] = silu(g[i]) * u[i]` in place — one
/// pass instead of materializing `silu(g)` and multiplying separately.
pub fn silu_mul(g: &mut [f32], u: &[f32]) {
    debug_assert_eq!(g.len(), u.len());
    for (gv, &uv) in g.iter_mut().zip(u.iter()) {
        *gv = silu(*gv) * uv;
    }
}

/// Mode-dispatched [`rmsnorm_into`].
pub fn rmsnorm_into_mode(
    h: &[f32],
    scale: &[f32],
    d: usize,
    eps: f32,
    out: &mut [f32],
    mode: KernelMode,
) {
    if simd_on(mode) {
        #[cfg(target_arch = "x86_64")]
        {
            debug_assert_eq!(h.len() % d, 0);
            debug_assert_eq!(scale.len(), d);
            debug_assert_eq!(out.len(), h.len());
            unsafe { simd::rmsnorm_into(h, scale, d, eps, out) };
            return;
        }
    }
    rmsnorm_into(h, scale, d, eps, out);
}

/// Mode-dispatched [`silu_mul`].
pub fn silu_mul_mode(g: &mut [f32], u: &[f32], mode: KernelMode) {
    if simd_on(mode) {
        #[cfg(target_arch = "x86_64")]
        {
            debug_assert_eq!(g.len(), u.len());
            unsafe { simd::silu_mul(g, u) };
            return;
        }
    }
    silu_mul(g, u);
}

/// Mode-dispatched [`softmax_rows`].
pub fn softmax_rows_mode(x: &mut [f32], n: usize, mode: KernelMode) {
    if simd_on(mode) {
        #[cfg(target_arch = "x86_64")]
        {
            debug_assert_eq!(x.len() % n, 0);
            unsafe { simd::softmax_rows(x, n) };
            return;
        }
    }
    softmax_rows(x, n);
}

/// Router scores into caller buffers: `out = softmax(rmsnorm(h, n2) @
/// w)` with `hn` as the `[B, D]` norm scratch — the allocation-free form
/// the per-layer hot path uses (scratch comes from the backend pool).
#[allow(clippy::too_many_arguments)]
pub fn router_scores_into(
    h: &[f32],
    n2: &[f32],
    w: &[f32],
    b: usize,
    d: usize,
    n_experts: usize,
    eps: f32,
    hn: &mut [f32],
    out: &mut [f32],
    mode: KernelMode,
) {
    debug_assert_eq!(hn.len(), b * d);
    debug_assert_eq!(out.len(), b * n_experts);
    rmsnorm_into_mode(h, n2, d, eps, hn, mode);
    matmul_packed_mode(hn, d, w, d, n_experts, b, out, mode);
    softmax_rows_mode(out, n_experts, mode);
}

/// Router scores: `softmax(rmsnorm(h, n2) @ w)` (ref.router_scores_ref).
/// Allocating wrapper over [`router_scores_into`] at scalar mode — kept
/// as the golden-fixture entry point.
pub fn router_scores(
    h: &[f32],
    n2: &[f32],
    w: &[f32],
    b: usize,
    d: usize,
    n_experts: usize,
    eps: f32,
) -> Vec<f32> {
    let mut hn = vec![0.0f32; b * d];
    let mut s = vec![0.0f32; b * n_experts];
    router_scores_into(h, n2, w, b, d, n_experts, eps, &mut hn, &mut s, KernelMode::Scalar);
    s
}

/// RoPE over `x [rows, heads, hd]` with per-row positions, pairing
/// `(i, i + hd/2)` exactly like `model.rope`.
pub fn rope(x: &mut [f32], heads: usize, hd: usize, pos: &[i32], theta: f32) {
    let half = hd / 2;
    debug_assert_eq!(x.len(), pos.len() * heads * hd);
    for (r, &p) in pos.iter().enumerate() {
        for hh in 0..heads {
            let base = (r * heads + hh) * hd;
            for i in 0..half {
                let freq = theta.powf(-(i as f32) / half as f32);
                let ang = p as f32 * freq;
                let (sin, cos) = ang.sin_cos();
                let x1 = x[base + i];
                let x2 = x[base + half + i];
                x[base + i] = x1 * cos - x2 * sin;
                x[base + half + i] = x1 * sin + x2 * cos;
            }
        }
    }
}

/// Decode attention for a contiguous span of batch rows (the threadpool
/// work item): GQA with `n_rep = Hq / Hkv`, causal mask `s <= pos[row]`,
/// softmax over the visible prefix. `k_cache`/`v_cache` are the full
/// `[B, S, Hkv, hd]` halves of the layer cache; `out` covers rows
/// `row0 ..` (its length picks the span) and `logits` is caller scratch
/// of at least `s_max` elements. Per-row math is independent of the
/// span, so any chunking of the batch produces identical results.
pub fn decode_attention_rows(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    s_max: usize,
    hq: usize,
    hkv: usize,
    hd: usize,
    pos: &[i32],
    row0: usize,
    out: &mut [f32],
    logits: &mut [f32],
) {
    let n_rep = hq / hkv;
    let scale = 1.0 / (hd as f32).sqrt();
    let rows = out.len() / (hq * hd);
    debug_assert!(logits.len() >= s_max);
    debug_assert!(row0 + rows <= pos.len());
    out.fill(0.0);
    for li in 0..rows {
        let i = row0 + li;
        let visible = (pos[i].max(0) as usize + 1).min(s_max);
        for h in 0..hq {
            let kvh = h / n_rep;
            let qrow = &q[(i * hq + h) * hd..(i * hq + h + 1) * hd];
            for (s, l) in logits[..visible].iter_mut().enumerate() {
                let krow = &k_cache[((i * s_max + s) * hkv + kvh) * hd..][..hd];
                let mut dot = 0.0f32;
                for (qv, kv) in qrow.iter().zip(krow.iter()) {
                    dot += qv * kv;
                }
                *l = dot * scale;
            }
            softmax_rows(&mut logits[..visible], visible);
            let orow = &mut out[(li * hq + h) * hd..(li * hq + h + 1) * hd];
            for (s, &p) in logits[..visible].iter().enumerate() {
                let vrow = &v_cache[((i * s_max + s) * hkv + kvh) * hd..][..hd];
                for (o, &vv) in orow.iter_mut().zip(vrow.iter()) {
                    *o += p * vv;
                }
            }
        }
    }
}

/// Whole-batch decode attention (ref.decode_attention_ref); allocating
/// wrapper over [`decode_attention_rows`]. Returns `[B, Hq, hd]`.
pub fn decode_attention(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    b: usize,
    s_max: usize,
    hq: usize,
    hkv: usize,
    hd: usize,
    pos: &[i32],
) -> Vec<f32> {
    debug_assert_eq!(q.len(), b * hq * hd);
    debug_assert_eq!(k_cache.len(), b * s_max * hkv * hd);
    let mut out = vec![0.0f32; b * hq * hd];
    let mut logits = vec![0.0f32; s_max];
    decode_attention_rows(
        q, k_cache, v_cache, s_max, hq, hkv, hd, pos, 0, &mut out, &mut logits,
    );
    out
}

/// Causal attention for a chunk of `C` consecutive prompt tokens that all
/// live in ONE sequence slot (the chunked-prefill primitive): query row
/// `j` holds position `pos0 + j` and attends the slot's cache prefix
/// `0 ..= pos0 + j`. `k_slot`/`v_slot` are that slot's `[S, Hkv, hd]`
/// cache slices (the chunk's own K/V must already be written). The
/// per-(row, head) inner math — dot, scale, softmax over the visible
/// prefix, weighted V sum — is copied from [`decode_attention_rows`]
/// verbatim so a chunked prefill is bitwise-identical per row to the
/// token-by-token decode-path prefill.
pub fn chunk_attention_rows(
    q: &[f32],
    k_slot: &[f32],
    v_slot: &[f32],
    s_max: usize,
    hq: usize,
    hkv: usize,
    hd: usize,
    pos0: usize,
    out: &mut [f32],
    logits: &mut [f32],
) {
    let n_rep = hq / hkv;
    let scale = 1.0 / (hd as f32).sqrt();
    let rows = out.len() / (hq * hd);
    debug_assert!(logits.len() >= s_max);
    debug_assert_eq!(k_slot.len(), s_max * hkv * hd);
    debug_assert!(pos0 + rows <= s_max);
    out.fill(0.0);
    for j in 0..rows {
        let visible = (pos0 + j + 1).min(s_max);
        for h in 0..hq {
            let kvh = h / n_rep;
            let qrow = &q[(j * hq + h) * hd..(j * hq + h + 1) * hd];
            for (s, l) in logits[..visible].iter_mut().enumerate() {
                let krow = &k_slot[(s * hkv + kvh) * hd..][..hd];
                let mut dot = 0.0f32;
                for (qv, kv) in qrow.iter().zip(krow.iter()) {
                    dot += qv * kv;
                }
                *l = dot * scale;
            }
            softmax_rows(&mut logits[..visible], visible);
            let orow = &mut out[(j * hq + h) * hd..(j * hq + h + 1) * hd];
            for (s, &p) in logits[..visible].iter().enumerate() {
                let vrow = &v_slot[(s * hkv + kvh) * hd..][..hd];
                for (o, &vv) in orow.iter_mut().zip(vrow.iter()) {
                    *o += p * vv;
                }
            }
        }
    }
}

/// Gather-based grouped expert FFN (ref.moe_ffn_gathered), the
/// correctness oracle for grouped dispatch: iterate the padded active
/// list, `out += comb[:, e] * (silu(x@wg[e]) * (x@wu[e])) @ wd[e]`.
/// Zero-combine padding ids contribute nothing but still run their
/// full-batch GEMMs — the measured work is proportional to `ids.len() ·
/// B` (the executed T bucket times the batch), exactly like the gathered
/// device kernel. `x` is the already-normed input `[B, D]`; adds into
/// `out [B, D]` (the caller owns the residual); `arena` supplies the
/// GEMM scratch.
pub fn moe_ffn_gather_into(
    x: &[f32],
    wg: &[f32],
    wu: &[f32],
    wd: &[f32],
    comb: &[f32],
    ids: &[i32],
    b: usize,
    d: usize,
    h: usize,
    n_experts: usize,
    out: &mut [f32],
    arena: &mut Arena,
) {
    debug_assert_eq!(x.len(), b * d);
    debug_assert_eq!(comb.len(), b * n_experts);
    debug_assert_eq!(out.len(), b * d);
    let mut g = arena.take(b * h);
    let mut u = arena.take(b * h);
    let mut y = arena.take(b * d);
    for &id in ids {
        let e = id as usize;
        debug_assert!(e < n_experts);
        let wg_e = &wg[e * d * h..(e + 1) * d * h];
        let wu_e = &wu[e * d * h..(e + 1) * d * h];
        let wd_e = &wd[e * h * d..(e + 1) * h * d];
        matmul_into(x, wg_e, b, d, h, &mut g);
        matmul_into(x, wu_e, b, d, h, &mut u);
        silu_mul(&mut g, &u);
        matmul_into(&g, wd_e, b, h, d, &mut y);
        for i in 0..b {
            let c = comb[i * n_experts + e];
            if c == 0.0 {
                continue;
            }
            let orow = &mut out[i * d..(i + 1) * d];
            let yrow = &y[i * d..(i + 1) * d];
            for (o, &yv) in orow.iter_mut().zip(yrow.iter()) {
                *o += c * yv;
            }
        }
    }
    arena.put(y);
    arena.put(u);
    arena.put(g);
}

/// Allocating wrapper over [`moe_ffn_gather_into`]. Returns the FFN
/// output `[B, D]` (the caller adds the residual).
pub fn moe_ffn_gather(
    x: &[f32],
    wg: &[f32],
    wu: &[f32],
    wd: &[f32],
    comb: &[f32],
    ids: &[i32],
    b: usize,
    d: usize,
    h: usize,
    n_experts: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; b * d];
    let mut arena = Arena::new();
    moe_ffn_gather_into(x, wg, wu, wd, comb, ids, b, d, h, n_experts, &mut out, &mut arena);
    out
}

/// One expert group's FFN through that expert's packed panels: gather the
/// routed `rows` of `x [B, D]` into a contiguous mini-batch, run the
/// SwiGLU FFN through `[D, h_pad]`/`[H, d_pad]` panels, and scatter-add
/// the combine-weighted result into `acc [B, D]`. Shared by the
/// whole-layer pack ([`moe_ffn_groups`]) and the residency path's
/// lazily-paged per-expert panels — the same micro-kernels run on the
/// same panel bytes, so the two paths are bitwise-identical.
#[allow(clippy::too_many_arguments)]
pub fn moe_ffn_group_rows(
    x: &[f32],
    wg_panel: PanelView<'_>,
    wu_panel: PanelView<'_>,
    wd_panel: PanelView<'_>,
    d: usize,
    h: usize,
    h_pad: usize,
    d_pad: usize,
    rows: &[u32],
    weights: &[f32],
    acc: &mut [f32],
    arena: &mut Arena,
    mode: KernelMode,
) {
    let m = rows.len();
    if m == 0 {
        return;
    }
    debug_assert_eq!(rows.len(), weights.len());
    debug_assert_eq!(wg_panel.len(), d * h_pad);
    debug_assert_eq!(wu_panel.len(), d * h_pad);
    debug_assert_eq!(wd_panel.len(), h * d_pad);
    let mut xg = arena.take(m * d);
    let mut g = arena.take(m * h_pad);
    let mut u = arena.take(m * h_pad);
    let mut y = arena.take(m * d_pad);
    for (j, &r) in rows.iter().enumerate() {
        let r = r as usize;
        xg[j * d..(j + 1) * d].copy_from_slice(&x[r * d..(r + 1) * d]);
    }
    matmul_view(&xg, d, wg_panel, d, h_pad, m, &mut g, mode);
    matmul_view(&xg, d, wu_panel, d, h_pad, m, &mut u, mode);
    silu_mul_mode(&mut g, &u, mode);
    matmul_view(&g, h_pad, wd_panel, h, d_pad, m, &mut y, mode);
    for (j, (&r, &w)) in rows.iter().zip(weights.iter()).enumerate() {
        let r = r as usize;
        let orow = &mut acc[r * d..(r + 1) * d];
        let yrow = &y[j * d_pad..j * d_pad + d];
        for (o, &yv) in orow.iter_mut().zip(yrow.iter()) {
            *o += w * yv;
        }
    }
    arena.put(y);
    arena.put(u);
    arena.put(g);
    arena.put(xg);
}

/// Token-grouped expert FFN over groups `g0..g1` of the work-list: for
/// each expert, gather its routed rows from `x [B, D]` into a contiguous
/// mini-batch, run the expert's SwiGLU FFN on just those rows through the
/// packed panels, and scatter-add the combine-weighted result into
/// `acc [B, D]`. Work is `Σ_g |rows(g)| · 3DH` — the routed load, not
/// `T · B`. Groups must be processed in ascending-expert order for the
/// per-token sums to match the gather oracle bitwise; `ExpertGroups`
/// guarantees that order and disjoint `g0..g1` ranges preserve it.
///
/// `e_base` is the first expert id of the panel shard: the packed mats
/// may hold a contiguous sub-range of the expert axis (an EP rank's
/// shard), indexed by `expert - e_base`. A whole-layer pack passes 0.
/// Per-expert panel rows are byte-identical however the shard was cut,
/// so sharded execution is bitwise-equal to whole-layer execution.
#[allow(clippy::too_many_arguments)]
pub fn moe_ffn_groups(
    x: &[f32],
    wg: &PackedMat,
    wu: &PackedMat,
    wd: &PackedMat,
    e_base: usize,
    groups: &ExpertGroups,
    g0: usize,
    g1: usize,
    acc: &mut [f32],
    arena: &mut Arena,
    mode: KernelMode,
) {
    let d = wg.k;
    let h = wd.k;
    let h_pad = wg.n_pad;
    let d_pad = wd.n_pad;
    debug_assert_eq!(wu.k, d);
    debug_assert_eq!(wu.n_pad, h_pad);
    debug_assert_eq!(wg.n, h);
    debug_assert_eq!(wd.n, d);
    debug_assert_eq!(acc.len() % d, 0);
    for gi in g0..g1 {
        let grp = groups.group(gi);
        debug_assert!(grp.expert >= e_base, "group expert outside the panel shard");
        let e = grp.expert - e_base;
        moe_ffn_group_rows(
            x,
            wg.expert_view(e),
            wu.expert_view(e),
            wd.expert_view(e),
            d,
            h,
            h_pad,
            d_pad,
            grp.rows,
            grp.weights,
            acc,
            arena,
            mode,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::policy::{route, Policy, RoutingInput};
    use crate::moe::ScoreMatrix;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let id = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &id, 2, 2, 2), a);
    }

    #[test]
    fn matmul_microkernel_matches_naive() {
        // odd m exercises both the 4-row block and the remainder path
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(1usize, 5usize, 3usize), (4, 8, 8), (7, 16, 24), (9, 3, 40)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.gaussian() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.gaussian() as f32).collect();
            let got = matmul(&a, &b, m, k, n);
            let mut want = vec![0.0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0f32;
                    for kk in 0..k {
                        s += a[i * k + kk] * b[kk * n + j];
                    }
                    want[i * n + j] = s;
                }
            }
            for (g, w) in got.iter().zip(want.iter()) {
                assert!((g - w).abs() < 1e-4, "({m},{k},{n}): {g} vs {w}");
            }
        }
    }

    #[test]
    fn packed_pads_to_lanes_and_preserves_rows() {
        // n = 5 pads to 8, with zeros beyond column 5
        let raw: Vec<f32> = (0..2 * 3 * 5).map(|x| x as f32).collect();
        let p = PackedMat::pack(&raw, 2, 3, 5);
        assert_eq!(p.n_pad, 8);
        let e1 = p.expert(1);
        assert_eq!(e1.len(), 3 * 8);
        assert_eq!(e1[0], raw[3 * 5]);
        assert_eq!(&e1[5..8], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn matmul_packed_matches_dense_with_padding() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (6usize, 7usize, 5usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gaussian() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gaussian() as f32).collect();
        let p = PackedMat::pack(&b, 1, k, n);
        let mut out = vec![1.0f32; m * p.n_pad]; // dirty: kernel must overwrite
        matmul_packed(&a, k, p.expert(0), k, p.n_pad, m, &mut out);
        let want = matmul(&a, &b, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let (g, w) = (out[i * p.n_pad + j], want[i * n + j]);
                assert!((g - w).abs() < 1e-5, "[{i},{j}] {g} vs {w}");
            }
            for j in n..p.n_pad {
                assert_eq!(out[i * p.n_pad + j], 0.0, "pad column leaked");
            }
        }
    }

    #[test]
    fn silu_mul_fuses_activation() {
        let mut g = vec![-1.0f32, 0.0, 2.0];
        let u = vec![3.0f32, 5.0, -1.5];
        let want: Vec<f32> = g.iter().zip(u.iter()).map(|(&gv, &uv)| silu(gv) * uv).collect();
        silu_mul(&mut g, &u);
        assert_eq!(g, want);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.iter().all(|&p| p > 0.0));
        }
    }

    #[test]
    fn rmsnorm_unit_scale_unit_rows() {
        let h = vec![3.0f32, 4.0, 0.0, 0.0];
        let scale = vec![1.0f32; 4];
        let out = rmsnorm(&h, &scale, 4, 0.0);
        let ms: f32 = out.iter().map(|&x| x * x).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rope_at_pos_zero_is_identity() {
        let orig = vec![0.5f32, -1.0, 2.0, 0.25];
        let mut x = orig.clone();
        rope(&mut x, 1, 4, &[0], 10000.0);
        for (a, b) in x.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rope_preserves_pair_norm() {
        let mut x = vec![0.5f32, -1.0, 2.0, 0.25];
        let norm0: f32 = x.iter().map(|v| v * v).sum();
        rope(&mut x, 1, 4, &[17], 10000.0);
        let norm1: f32 = x.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() < 1e-4);
    }

    #[test]
    fn attention_single_visible_token_copies_v() {
        // pos = 0: only cache slot 0 visible, attention output == v[0]
        let (b, s, hq, hkv, hd) = (1, 4, 2, 1, 2);
        let q = vec![0.3f32; hq * hd];
        let mut k = vec![0.0f32; s * hkv * hd];
        let mut v = vec![0.0f32; s * hkv * hd];
        k[0] = 1.0;
        v[0] = 5.0;
        v[1] = -2.0;
        let out = decode_attention(&q, &k, &v, b, s, hq, hkv, hd, &[0]);
        for h in 0..hq {
            assert!((out[h * hd] - 5.0).abs() < 1e-6);
            assert!((out[h * hd + 1] + 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn attention_row_spans_compose() {
        // computing rows [0,2) and [2,4) separately must equal the whole
        let (b, s, hq, hkv, hd) = (4usize, 6usize, 2usize, 1usize, 4usize);
        let mut rng = Rng::new(9);
        let q: Vec<f32> = (0..b * hq * hd).map(|_| rng.gaussian() as f32).collect();
        let k: Vec<f32> = (0..b * s * hkv * hd).map(|_| rng.gaussian() as f32).collect();
        let v: Vec<f32> = (0..b * s * hkv * hd).map(|_| rng.gaussian() as f32).collect();
        let pos = vec![3i32, 0, 5, 2];
        let whole = decode_attention(&q, &k, &v, b, s, hq, hkv, hd, &pos);
        let mut parts = vec![0.0f32; b * hq * hd];
        let mut logits = vec![0.0f32; s];
        let half = 2 * hq * hd;
        {
            let (lo, hi) = parts.split_at_mut(half);
            decode_attention_rows(&q, &k, &v, s, hq, hkv, hd, &pos, 0, lo, &mut logits);
            decode_attention_rows(&q, &k, &v, s, hq, hkv, hd, &pos, 2, hi, &mut logits);
        }
        assert_eq!(whole, parts);
    }

    #[test]
    fn moe_padding_id_contributes_nothing() {
        let (b, d, h, n) = (2, 3, 4, 3);
        let x = vec![0.2f32; b * d];
        let wg = vec![0.1f32; n * d * h];
        let wu = vec![0.1f32; n * d * h];
        let wd = vec![0.1f32; n * h * d];
        // only expert 0 has combine mass
        let mut comb = vec![0.0f32; b * n];
        comb[0] = 1.0;
        comb[n] = 1.0;
        let a = moe_ffn_gather(&x, &wg, &wu, &wd, &comb, &[0], b, d, h, n);
        let bb = moe_ffn_gather(&x, &wg, &wu, &wd, &comb, &[0, 2, 2], b, d, h, n);
        for (x1, x2) in a.iter().zip(bb.iter()) {
            assert!((x1 - x2).abs() < 1e-6);
        }
    }

    #[test]
    fn grouped_ffn_matches_gather_oracle() {
        let (b, d, h, n) = (5usize, 8usize, 6usize, 4usize);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..b * d).map(|_| rng.gaussian() as f32).collect();
        let wg: Vec<f32> = (0..n * d * h).map(|_| rng.gaussian() as f32 * 0.2).collect();
        let wu: Vec<f32> = (0..n * d * h).map(|_| rng.gaussian() as f32 * 0.2).collect();
        let wd: Vec<f32> = (0..n * h * d).map(|_| rng.gaussian() as f32 * 0.2).collect();
        // random-ish sparse combine (some zero rows / zero entries)
        let mut comb = vec![0.0f32; b * n];
        for i in 0..b {
            for e in 0..n {
                if (i + e) % 3 != 0 {
                    comb[i * n + e] = 0.1 + ((i * n + e) % 7) as f32 * 0.1;
                }
            }
        }
        let ids: Vec<i32> = (0..n as i32).collect();
        let want = moe_ffn_gather(&x, &wg, &wu, &wd, &comb, &ids, b, d, h, n);
        let pg = PackedMat::pack(&wg, n, d, h);
        let pu = PackedMat::pack(&wu, n, d, h);
        let pd = PackedMat::pack(&wd, n, h, d);
        let groups = ExpertGroups::from_combine(&comb, &ids, b, n);
        let mut acc = vec![0.0f32; b * d];
        let mut arena = Arena::new();
        let sc = KernelMode::Scalar;
        moe_ffn_groups(&x, &pg, &pu, &pd, 0, &groups, 0, groups.len(), &mut acc, &mut arena, sc);
        for (i, (g, w)) in acc.iter().zip(want.iter()).enumerate() {
            assert!((g - w).abs() < 1e-5, "[{i}] grouped {g} vs gather {w}");
        }
        // split ranges (the parallel chunking) must also agree
        let mut acc2 = vec![0.0f32; b * d];
        let mid = groups.len() / 2;
        moe_ffn_groups(&x, &pg, &pu, &pd, 0, &groups, 0, mid, &mut acc2, &mut arena, sc);
        moe_ffn_groups(&x, &pg, &pu, &pd, 0, &groups, mid, groups.len(), &mut acc2, &mut arena, sc);
        assert_eq!(acc, acc2);
    }

    #[test]
    fn grouped_ffn_skips_unrouted_tokens() {
        // a token with zero combine everywhere must not affect any output
        let (b, d, h, n) = (3usize, 4usize, 4usize, 2usize);
        let x = vec![0.5f32; b * d];
        let wg = vec![0.1f32; n * d * h];
        let wu = vec![0.2f32; n * d * h];
        let wd = vec![0.3f32; n * h * d];
        let mut comb = vec![0.0f32; b * n];
        comb[0] = 1.0; // token 0 -> expert 0; tokens 1,2 unrouted
        let pg = PackedMat::pack(&wg, n, d, h);
        let pu = PackedMat::pack(&wu, n, d, h);
        let pd = PackedMat::pack(&wd, n, h, d);
        let groups = ExpertGroups::from_combine(&comb, &[0, 1], b, n);
        assert_eq!(groups.routed_tokens(), 1);
        let mut acc = vec![0.0f32; b * d];
        let mut arena = Arena::new();
        let sc = KernelMode::Scalar;
        moe_ffn_groups(&x, &pg, &pu, &pd, 0, &groups, 0, groups.len(), &mut acc, &mut arena, sc);
        assert!(acc[..d].iter().all(|&v| v != 0.0));
        assert!(acc[d..].iter().all(|&v| v == 0.0), "unrouted rows touched");
    }

    #[test]
    fn grouped_ffn_from_decision_route() {
        // end-to-end through a routing decision, per-expert order stable
        let scores = vec![
            0.6, 0.3, 0.1, //
            0.2, 0.5, 0.3, //
        ];
        let s = ScoreMatrix::new(2, 3, scores);
        let live = vec![true; 2];
        let d_route = route(
            Policy::Vanilla { k: 2 },
            &RoutingInput::new(&s, &live, true),
        );
        let groups = ExpertGroups::from_decision(&d_route);
        assert_eq!(groups.routed_tokens(), 4);
        let (b, d, h, n) = (2usize, 4usize, 4usize, 3usize);
        let mut rng = Rng::new(21);
        let x: Vec<f32> = (0..b * d).map(|_| rng.gaussian() as f32).collect();
        let wg: Vec<f32> = (0..n * d * h).map(|_| rng.gaussian() as f32 * 0.3).collect();
        let wu: Vec<f32> = (0..n * d * h).map(|_| rng.gaussian() as f32 * 0.3).collect();
        let wd: Vec<f32> = (0..n * h * d).map(|_| rng.gaussian() as f32 * 0.3).collect();
        let ids: Vec<i32> = d_route.active.iter().map(|&e| e as i32).collect();
        let want = moe_ffn_gather(&x, &wg, &wu, &wd, &d_route.combine, &ids, b, d, h, n);
        let pg = PackedMat::pack(&wg, n, d, h);
        let pu = PackedMat::pack(&wu, n, d, h);
        let pd = PackedMat::pack(&wd, n, h, d);
        let mut acc = vec![0.0f32; b * d];
        let mut arena = Arena::new();
        let sc = KernelMode::Scalar;
        moe_ffn_groups(&x, &pg, &pu, &pd, 0, &groups, 0, groups.len(), &mut acc, &mut arena, sc);
        for (g, w) in acc.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn bf16_round_trip_is_exact_for_bf16_values() {
        for x in [0.0f32, 1.0, -2.5, 0.15625, -1024.0] {
            let u = bf16_from_f32(x);
            let y = bf16_to_f32(u);
            // these values are exactly representable in bf16
            assert_eq!(x, y, "{x} -> {u:#06x} -> {y}");
        }
        // rounding is to nearest: error bounded by 2^-8 relative
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let x = rng.gaussian() as f32 * 3.0;
            let y = bf16_to_f32(bf16_from_f32(x));
            assert!((x - y).abs() <= x.abs() * 0.004 + 1e-30, "{x} vs {y}");
        }
    }

    #[test]
    fn quantized_packs_report_smaller_bytes() {
        let raw: Vec<f32> = (0..4 * 6 * 8).map(|x| (x as f32).sin()).collect();
        let f = PackedMat::pack_dtype(&raw, 4, 6, 8, PanelDtype::F32);
        let b = PackedMat::pack_dtype(&raw, 4, 6, 8, PanelDtype::Bf16);
        let q = PackedMat::pack_dtype(&raw, 4, 6, 8, PanelDtype::Int8);
        assert_eq!(f.bytes(), 4 * 6 * 8 * 4);
        assert_eq!(b.bytes(), f.bytes() / 2);
        // int8: 1 byte/elem + one f32 scale per packed row
        assert_eq!(q.bytes(), 4 * 6 * 8 + 4 * 6 * 4);
        assert!(f.bytes() as f64 / q.bytes() as f64 >= 3.0);
    }

    #[test]
    fn int8_quantization_error_bounded_by_half_scale_step() {
        let mut rng = Rng::new(13);
        let (e, k, n) = (2usize, 5usize, 11usize);
        let raw: Vec<f32> = (0..e * k * n).map(|_| rng.gaussian() as f32).collect();
        let p = PackedMat::pack_dtype(&raw, e, k, n, PanelDtype::Int8);
        for ei in 0..e {
            let (q, scale) = match p.expert_view(ei) {
                PanelView::I8 { q, scale } => (q, scale),
                _ => unreachable!(),
            };
            for kk in 0..k {
                for j in 0..n {
                    let x = raw[(ei * k + kk) * n + j];
                    let deq = q[kk * p.n_pad + j] as f32 * scale[kk];
                    assert!(
                        (x - deq).abs() <= scale[kk] * 0.5 + 1e-7,
                        "[{ei},{kk},{j}] {x} vs {deq} (scale {})",
                        scale[kk]
                    );
                }
            }
        }
    }

    #[test]
    fn quantized_matmul_matches_dequantized_dense() {
        // the fused-dequant GEMMs must equal an f32 GEMM over the
        // explicitly dequantized panel (same math, different fusion)
        let mut rng = Rng::new(17);
        let (m, k, n) = (5usize, 7usize, 12usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gaussian() as f32).collect();
        let raw: Vec<f32> = (0..k * n).map(|_| rng.gaussian() as f32).collect();
        for dtype in [PanelDtype::Bf16, PanelDtype::Int8] {
            let p = PackedMat::pack_dtype(&raw, 1, k, n, dtype);
            let mut got = vec![0.0f32; m * p.n_pad];
            matmul_view(&a, k, p.expert_view(0), k, p.n_pad, m, &mut got, KernelMode::Scalar);
            // dequantize then run the f32 kernel
            let deq: Vec<f32> = (0..k * p.n_pad)
                .map(|i| match p.expert_view(0) {
                    PanelView::Bf16(d) => bf16_to_f32(d[i]),
                    PanelView::I8 { q, scale } => q[i] as f32 * scale[i / p.n_pad],
                    PanelView::F32(d) => d[i],
                })
                .collect();
            let mut want = vec![0.0f32; m * p.n_pad];
            matmul_packed(&a, k, &deq, k, p.n_pad, m, &mut want);
            for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                assert!((g - w).abs() < 1e-4, "{dtype:?}[{i}] {g} vs {w}");
            }
        }
    }

    #[test]
    fn simd_mode_falls_back_and_matches_scalar() {
        // whatever the host CPU, the mode-dispatched wrappers must stay
        // within equivalence tolerance of the scalar oracle (on non-AVX2
        // hosts they ARE the scalar oracle)
        let mut rng = Rng::new(23);
        let (m, k, n) = (6usize, 9usize, 16usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gaussian() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gaussian() as f32).collect();
        let mut want = vec![0.0f32; m * n];
        matmul_packed(&a, k, &b, k, n, m, &mut want);
        let mut got = vec![0.0f32; m * n];
        matmul_packed_mode(&a, k, &b, k, n, m, &mut got, KernelMode::Simd);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-4);
        }
        let g0: Vec<f32> = (0..64).map(|_| rng.gaussian() as f32).collect();
        let u: Vec<f32> = (0..64).map(|_| rng.gaussian() as f32).collect();
        let mut gs = g0.clone();
        silu_mul(&mut gs, &u);
        let mut gv = g0.clone();
        silu_mul_mode(&mut gv, &u, KernelMode::Simd);
        for (a1, b1) in gs.iter().zip(gv.iter()) {
            assert!((a1 - b1).abs() < 1e-4);
        }
    }
}
