//! Pure-Rust kernels mirroring `python/compile/kernels/ref.py` (the
//! cross-language correctness ground truth), engineered for the decode
//! hot path:
//!
//! - one cache-blocked GEMM micro-kernel ([`matmul_packed`]) behind both
//!   the dense [`matmul`] and the pre-transposed/padded expert weight
//!   layout ([`PackedMat`]) — 4 output rows per pass so each streamed
//!   weight row is reused 4×, with a branch-free autovectorizable inner
//!   loop (the old `if av == 0.0` skip pessimized dense rows and is
//!   obsolete now that zero-combine tokens are never dispatched);
//! - a fused `silu(g) · u` activation ([`silu_mul`]);
//! - `_into` variants that write caller-provided buffers, with an
//!   [`Arena`] supplying scratch so the hot loop performs no per-call
//!   heap allocation;
//! - the token-grouped expert FFN ([`moe_ffn_groups`]) executing an
//!   [`ExpertGroups`] work-list, and the original gather-style kernel
//!   ([`moe_ffn_gather`]) kept as the correctness oracle.
//!
//! All math is f32; golden fixtures in `rust/tests/cpu_backend_golden.rs`
//! pin these against the JAX oracles. Per-row results are independent of
//! batch composition (each output element accumulates over `k` in the
//! same order regardless of how rows are grouped or chunked), which is
//! what makes serial grouped dispatch bitwise-identical to the gather
//! oracle's per-token math; the threaded partial-accumulator reduce in
//! the backend adds only rounding-level (~ulp) reassociation.

use crate::moe::dispatch::ExpertGroups;
use crate::util::arena::Arena;

/// Pad width of packed weight columns (f32 lanes of one AVX2 register;
/// also divides every preset's `d_model`/`d_expert`, so padding is
/// usually a no-op).
pub const LANES: usize = 8;

/// A weight matrix (or a bank of per-expert matrices) pre-packed for
/// [`matmul_packed`]: row-major `[K, n_pad]` panels with `n_pad` the
/// column count rounded up to [`LANES`] and the padding columns zeroed.
/// The `[K, N]` orientation means the GEMM inner loop streams weight rows
/// contiguously (the layout `ref.py` already uses), and the padding keeps
/// every row a whole number of vector lanes.
#[derive(Debug, Clone)]
pub struct PackedMat {
    /// reduction dimension (rows of one panel)
    pub k: usize,
    /// logical output columns
    pub n: usize,
    /// padded output columns (row stride)
    pub n_pad: usize,
    /// number of stacked per-expert panels
    pub experts: usize,
    data: Vec<f32>,
}

impl PackedMat {
    /// Pack `experts` stacked `[k, n]` row-major matrices.
    pub fn pack(raw: &[f32], experts: usize, k: usize, n: usize) -> PackedMat {
        debug_assert_eq!(raw.len(), experts * k * n);
        let n_pad = n.div_ceil(LANES) * LANES;
        let mut data = vec![0.0f32; experts * k * n_pad];
        for row in 0..experts * k {
            data[row * n_pad..row * n_pad + n].copy_from_slice(&raw[row * n..(row + 1) * n]);
        }
        PackedMat { k, n, n_pad, experts, data }
    }

    /// Expert `e`'s `[k, n_pad]` panel.
    #[inline]
    pub fn expert(&self, e: usize) -> &[f32] {
        let stride = self.k * self.n_pad;
        &self.data[e * stride..(e + 1) * stride]
    }
}

/// Core GEMM micro-kernel: `out[m, n_pad] = a[m, k] @ panel[k, n_pad]`,
/// where `a` rows are `lda` elements apart (so callers can feed padded
/// scratch rows straight back in as the next GEMM's input). `out` is
/// overwritten. Processes 4 output rows per pass — the panel row loaded
/// in the inner loop is reused for all 4, and the 4-way accumulate over
/// a full vector row autovectorizes without branches. Output rows stay
/// L1-resident across the `k` sweep, which is the cache-blocking that
/// matters at decode shapes (`m <= B`, panel streamed once per 4 rows).
pub fn matmul_packed(
    a: &[f32],
    lda: usize,
    panel: &[f32],
    k: usize,
    n_pad: usize,
    m: usize,
    out: &mut [f32],
) {
    debug_assert!(m == 0 || a.len() >= (m - 1) * lda + k);
    debug_assert_eq!(panel.len(), k * n_pad);
    debug_assert_eq!(out.len(), m * n_pad);
    out.fill(0.0);
    let mut i = 0;
    while i + 4 <= m {
        let block = &mut out[i * n_pad..(i + 4) * n_pad];
        let (o0, rest) = block.split_at_mut(n_pad);
        let (o1, rest) = rest.split_at_mut(n_pad);
        let (o2, o3) = rest.split_at_mut(n_pad);
        let a0 = &a[i * lda..i * lda + k];
        let a1 = &a[(i + 1) * lda..(i + 1) * lda + k];
        let a2 = &a[(i + 2) * lda..(i + 2) * lda + k];
        let a3 = &a[(i + 3) * lda..(i + 3) * lda + k];
        for kk in 0..k {
            let brow = &panel[kk * n_pad..(kk + 1) * n_pad];
            let (c0, c1, c2, c3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
            let it = o0
                .iter_mut()
                .zip(o1.iter_mut())
                .zip(o2.iter_mut())
                .zip(o3.iter_mut())
                .zip(brow.iter());
            for ((((v0, v1), v2), v3), &bv) in it {
                *v0 += c0 * bv;
                *v1 += c1 * bv;
                *v2 += c2 * bv;
                *v3 += c3 * bv;
            }
        }
        i += 4;
    }
    while i < m {
        let orow = &mut out[i * n_pad..(i + 1) * n_pad];
        let arow = &a[i * lda..i * lda + k];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &panel[kk * n_pad..(kk + 1) * n_pad];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
        i += 1;
    }
}

/// `out[m, n] = a[m, k] @ b[k, n]` (row-major) into a caller buffer.
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    // a dense [K, N] matrix is a packed panel with n_pad = n
    matmul_packed(a, k, b, k, n, m, out);
}

/// Allocating wrapper over [`matmul_into`].
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_into(a, b, m, k, n, &mut out);
    out
}

/// RMSNorm per row into a caller buffer: `h / sqrt(mean(h^2) + eps) *
/// scale` (ref.rmsnorm_ref).
pub fn rmsnorm_into(h: &[f32], scale: &[f32], d: usize, eps: f32, out: &mut [f32]) {
    debug_assert_eq!(h.len() % d, 0);
    debug_assert_eq!(scale.len(), d);
    debug_assert_eq!(out.len(), h.len());
    for (row, orow) in h.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms: f32 = row.iter().map(|&x| x * x).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for ((o, &x), &s) in orow.iter_mut().zip(row.iter()).zip(scale.iter()) {
            *o = x * inv * s;
        }
    }
}

/// Allocating wrapper over [`rmsnorm_into`].
pub fn rmsnorm(h: &[f32], scale: &[f32], d: usize, eps: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; h.len()];
    rmsnorm_into(h, scale, d, eps, &mut out);
    out
}

/// Numerically-stable softmax over each row of `x [rows, n]`, in place.
pub fn softmax_rows(x: &mut [f32], n: usize) {
    debug_assert_eq!(x.len() % n, 0);
    for row in x.chunks_exact_mut(n) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Fused SwiGLU activation: `g[i] = silu(g[i]) * u[i]` in place — one
/// pass instead of materializing `silu(g)` and multiplying separately.
pub fn silu_mul(g: &mut [f32], u: &[f32]) {
    debug_assert_eq!(g.len(), u.len());
    for (gv, &uv) in g.iter_mut().zip(u.iter()) {
        *gv = silu(*gv) * uv;
    }
}

/// Router scores: `softmax(rmsnorm(h, n2) @ w)` (ref.router_scores_ref).
pub fn router_scores(
    h: &[f32],
    n2: &[f32],
    w: &[f32],
    b: usize,
    d: usize,
    n_experts: usize,
    eps: f32,
) -> Vec<f32> {
    let hn = rmsnorm(h, n2, d, eps);
    let mut s = matmul(&hn, w, b, d, n_experts);
    softmax_rows(&mut s, n_experts);
    s
}

/// RoPE over `x [rows, heads, hd]` with per-row positions, pairing
/// `(i, i + hd/2)` exactly like `model.rope`.
pub fn rope(x: &mut [f32], heads: usize, hd: usize, pos: &[i32], theta: f32) {
    let half = hd / 2;
    debug_assert_eq!(x.len(), pos.len() * heads * hd);
    for (r, &p) in pos.iter().enumerate() {
        for hh in 0..heads {
            let base = (r * heads + hh) * hd;
            for i in 0..half {
                let freq = theta.powf(-(i as f32) / half as f32);
                let ang = p as f32 * freq;
                let (sin, cos) = ang.sin_cos();
                let x1 = x[base + i];
                let x2 = x[base + half + i];
                x[base + i] = x1 * cos - x2 * sin;
                x[base + half + i] = x1 * sin + x2 * cos;
            }
        }
    }
}

/// Decode attention for a contiguous span of batch rows (the threadpool
/// work item): GQA with `n_rep = Hq / Hkv`, causal mask `s <= pos[row]`,
/// softmax over the visible prefix. `k_cache`/`v_cache` are the full
/// `[B, S, Hkv, hd]` halves of the layer cache; `out` covers rows
/// `row0 ..` (its length picks the span) and `logits` is caller scratch
/// of at least `s_max` elements. Per-row math is independent of the
/// span, so any chunking of the batch produces identical results.
pub fn decode_attention_rows(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    s_max: usize,
    hq: usize,
    hkv: usize,
    hd: usize,
    pos: &[i32],
    row0: usize,
    out: &mut [f32],
    logits: &mut [f32],
) {
    let n_rep = hq / hkv;
    let scale = 1.0 / (hd as f32).sqrt();
    let rows = out.len() / (hq * hd);
    debug_assert!(logits.len() >= s_max);
    debug_assert!(row0 + rows <= pos.len());
    out.fill(0.0);
    for li in 0..rows {
        let i = row0 + li;
        let visible = (pos[i].max(0) as usize + 1).min(s_max);
        for h in 0..hq {
            let kvh = h / n_rep;
            let qrow = &q[(i * hq + h) * hd..(i * hq + h + 1) * hd];
            for (s, l) in logits[..visible].iter_mut().enumerate() {
                let krow = &k_cache[((i * s_max + s) * hkv + kvh) * hd..][..hd];
                let mut dot = 0.0f32;
                for (qv, kv) in qrow.iter().zip(krow.iter()) {
                    dot += qv * kv;
                }
                *l = dot * scale;
            }
            softmax_rows(&mut logits[..visible], visible);
            let orow = &mut out[(li * hq + h) * hd..(li * hq + h + 1) * hd];
            for (s, &p) in logits[..visible].iter().enumerate() {
                let vrow = &v_cache[((i * s_max + s) * hkv + kvh) * hd..][..hd];
                for (o, &vv) in orow.iter_mut().zip(vrow.iter()) {
                    *o += p * vv;
                }
            }
        }
    }
}

/// Whole-batch decode attention (ref.decode_attention_ref); allocating
/// wrapper over [`decode_attention_rows`]. Returns `[B, Hq, hd]`.
pub fn decode_attention(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    b: usize,
    s_max: usize,
    hq: usize,
    hkv: usize,
    hd: usize,
    pos: &[i32],
) -> Vec<f32> {
    debug_assert_eq!(q.len(), b * hq * hd);
    debug_assert_eq!(k_cache.len(), b * s_max * hkv * hd);
    let mut out = vec![0.0f32; b * hq * hd];
    let mut logits = vec![0.0f32; s_max];
    decode_attention_rows(
        q, k_cache, v_cache, s_max, hq, hkv, hd, pos, 0, &mut out, &mut logits,
    );
    out
}

/// Causal attention for a chunk of `C` consecutive prompt tokens that all
/// live in ONE sequence slot (the chunked-prefill primitive): query row
/// `j` holds position `pos0 + j` and attends the slot's cache prefix
/// `0 ..= pos0 + j`. `k_slot`/`v_slot` are that slot's `[S, Hkv, hd]`
/// cache slices (the chunk's own K/V must already be written). The
/// per-(row, head) inner math — dot, scale, softmax over the visible
/// prefix, weighted V sum — is copied from [`decode_attention_rows`]
/// verbatim so a chunked prefill is bitwise-identical per row to the
/// token-by-token decode-path prefill.
pub fn chunk_attention_rows(
    q: &[f32],
    k_slot: &[f32],
    v_slot: &[f32],
    s_max: usize,
    hq: usize,
    hkv: usize,
    hd: usize,
    pos0: usize,
    out: &mut [f32],
    logits: &mut [f32],
) {
    let n_rep = hq / hkv;
    let scale = 1.0 / (hd as f32).sqrt();
    let rows = out.len() / (hq * hd);
    debug_assert!(logits.len() >= s_max);
    debug_assert_eq!(k_slot.len(), s_max * hkv * hd);
    debug_assert!(pos0 + rows <= s_max);
    out.fill(0.0);
    for j in 0..rows {
        let visible = (pos0 + j + 1).min(s_max);
        for h in 0..hq {
            let kvh = h / n_rep;
            let qrow = &q[(j * hq + h) * hd..(j * hq + h + 1) * hd];
            for (s, l) in logits[..visible].iter_mut().enumerate() {
                let krow = &k_slot[(s * hkv + kvh) * hd..][..hd];
                let mut dot = 0.0f32;
                for (qv, kv) in qrow.iter().zip(krow.iter()) {
                    dot += qv * kv;
                }
                *l = dot * scale;
            }
            softmax_rows(&mut logits[..visible], visible);
            let orow = &mut out[(j * hq + h) * hd..(j * hq + h + 1) * hd];
            for (s, &p) in logits[..visible].iter().enumerate() {
                let vrow = &v_slot[(s * hkv + kvh) * hd..][..hd];
                for (o, &vv) in orow.iter_mut().zip(vrow.iter()) {
                    *o += p * vv;
                }
            }
        }
    }
}

/// Gather-based grouped expert FFN (ref.moe_ffn_gathered), the
/// correctness oracle for grouped dispatch: iterate the padded active
/// list, `out += comb[:, e] * (silu(x@wg[e]) * (x@wu[e])) @ wd[e]`.
/// Zero-combine padding ids contribute nothing but still run their
/// full-batch GEMMs — the measured work is proportional to `ids.len() ·
/// B` (the executed T bucket times the batch), exactly like the gathered
/// device kernel. `x` is the already-normed input `[B, D]`; adds into
/// `out [B, D]` (the caller owns the residual); `arena` supplies the
/// GEMM scratch.
pub fn moe_ffn_gather_into(
    x: &[f32],
    wg: &[f32],
    wu: &[f32],
    wd: &[f32],
    comb: &[f32],
    ids: &[i32],
    b: usize,
    d: usize,
    h: usize,
    n_experts: usize,
    out: &mut [f32],
    arena: &mut Arena,
) {
    debug_assert_eq!(x.len(), b * d);
    debug_assert_eq!(comb.len(), b * n_experts);
    debug_assert_eq!(out.len(), b * d);
    let mut g = arena.take(b * h);
    let mut u = arena.take(b * h);
    let mut y = arena.take(b * d);
    for &id in ids {
        let e = id as usize;
        debug_assert!(e < n_experts);
        let wg_e = &wg[e * d * h..(e + 1) * d * h];
        let wu_e = &wu[e * d * h..(e + 1) * d * h];
        let wd_e = &wd[e * h * d..(e + 1) * h * d];
        matmul_into(x, wg_e, b, d, h, &mut g);
        matmul_into(x, wu_e, b, d, h, &mut u);
        silu_mul(&mut g, &u);
        matmul_into(&g, wd_e, b, h, d, &mut y);
        for i in 0..b {
            let c = comb[i * n_experts + e];
            if c == 0.0 {
                continue;
            }
            let orow = &mut out[i * d..(i + 1) * d];
            let yrow = &y[i * d..(i + 1) * d];
            for (o, &yv) in orow.iter_mut().zip(yrow.iter()) {
                *o += c * yv;
            }
        }
    }
    arena.put(y);
    arena.put(u);
    arena.put(g);
}

/// Allocating wrapper over [`moe_ffn_gather_into`]. Returns the FFN
/// output `[B, D]` (the caller adds the residual).
pub fn moe_ffn_gather(
    x: &[f32],
    wg: &[f32],
    wu: &[f32],
    wd: &[f32],
    comb: &[f32],
    ids: &[i32],
    b: usize,
    d: usize,
    h: usize,
    n_experts: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; b * d];
    let mut arena = Arena::new();
    moe_ffn_gather_into(x, wg, wu, wd, comb, ids, b, d, h, n_experts, &mut out, &mut arena);
    out
}

/// One expert group's FFN through that expert's packed panels: gather the
/// routed `rows` of `x [B, D]` into a contiguous mini-batch, run the
/// SwiGLU FFN through `[D, h_pad]`/`[H, d_pad]` panels, and scatter-add
/// the combine-weighted result into `acc [B, D]`. Shared by the
/// whole-layer pack ([`moe_ffn_groups`]) and the residency path's
/// lazily-paged per-expert panels — the same micro-kernels run on the
/// same panel bytes, so the two paths are bitwise-identical.
pub fn moe_ffn_group_rows(
    x: &[f32],
    wg_panel: &[f32],
    wu_panel: &[f32],
    wd_panel: &[f32],
    d: usize,
    h: usize,
    h_pad: usize,
    d_pad: usize,
    rows: &[u32],
    weights: &[f32],
    acc: &mut [f32],
    arena: &mut Arena,
) {
    let m = rows.len();
    if m == 0 {
        return;
    }
    debug_assert_eq!(rows.len(), weights.len());
    debug_assert_eq!(wg_panel.len(), d * h_pad);
    debug_assert_eq!(wu_panel.len(), d * h_pad);
    debug_assert_eq!(wd_panel.len(), h * d_pad);
    let mut xg = arena.take(m * d);
    let mut g = arena.take(m * h_pad);
    let mut u = arena.take(m * h_pad);
    let mut y = arena.take(m * d_pad);
    for (j, &r) in rows.iter().enumerate() {
        let r = r as usize;
        xg[j * d..(j + 1) * d].copy_from_slice(&x[r * d..(r + 1) * d]);
    }
    matmul_packed(&xg, d, wg_panel, d, h_pad, m, &mut g);
    matmul_packed(&xg, d, wu_panel, d, h_pad, m, &mut u);
    silu_mul(&mut g, &u);
    matmul_packed(&g, h_pad, wd_panel, h, d_pad, m, &mut y);
    for (j, (&r, &w)) in rows.iter().zip(weights.iter()).enumerate() {
        let r = r as usize;
        let orow = &mut acc[r * d..(r + 1) * d];
        let yrow = &y[j * d_pad..j * d_pad + d];
        for (o, &yv) in orow.iter_mut().zip(yrow.iter()) {
            *o += w * yv;
        }
    }
    arena.put(y);
    arena.put(u);
    arena.put(g);
    arena.put(xg);
}

/// Token-grouped expert FFN over groups `g0..g1` of the work-list: for
/// each expert, gather its routed rows from `x [B, D]` into a contiguous
/// mini-batch, run the expert's SwiGLU FFN on just those rows through the
/// packed panels, and scatter-add the combine-weighted result into
/// `acc [B, D]`. Work is `Σ_g |rows(g)| · 3DH` — the routed load, not
/// `T · B`. Groups must be processed in ascending-expert order for the
/// per-token sums to match the gather oracle bitwise; `ExpertGroups`
/// guarantees that order and disjoint `g0..g1` ranges preserve it.
///
/// `e_base` is the first expert id of the panel shard: the packed mats
/// may hold a contiguous sub-range of the expert axis (an EP rank's
/// shard), indexed by `expert - e_base`. A whole-layer pack passes 0.
/// Per-expert panel rows are byte-identical however the shard was cut,
/// so sharded execution is bitwise-equal to whole-layer execution.
pub fn moe_ffn_groups(
    x: &[f32],
    wg: &PackedMat,
    wu: &PackedMat,
    wd: &PackedMat,
    e_base: usize,
    groups: &ExpertGroups,
    g0: usize,
    g1: usize,
    acc: &mut [f32],
    arena: &mut Arena,
) {
    let d = wg.k;
    let h = wd.k;
    let h_pad = wg.n_pad;
    let d_pad = wd.n_pad;
    debug_assert_eq!(wu.k, d);
    debug_assert_eq!(wu.n_pad, h_pad);
    debug_assert_eq!(wg.n, h);
    debug_assert_eq!(wd.n, d);
    debug_assert_eq!(acc.len() % d, 0);
    for gi in g0..g1 {
        let grp = groups.group(gi);
        debug_assert!(grp.expert >= e_base, "group expert outside the panel shard");
        let e = grp.expert - e_base;
        moe_ffn_group_rows(
            x,
            wg.expert(e),
            wu.expert(e),
            wd.expert(e),
            d,
            h,
            h_pad,
            d_pad,
            grp.rows,
            grp.weights,
            acc,
            arena,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::policy::{route, Policy, RoutingInput};
    use crate::moe::ScoreMatrix;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let id = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &id, 2, 2, 2), a);
    }

    #[test]
    fn matmul_microkernel_matches_naive() {
        // odd m exercises both the 4-row block and the remainder path
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(1usize, 5usize, 3usize), (4, 8, 8), (7, 16, 24), (9, 3, 40)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.gaussian() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.gaussian() as f32).collect();
            let got = matmul(&a, &b, m, k, n);
            let mut want = vec![0.0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0f32;
                    for kk in 0..k {
                        s += a[i * k + kk] * b[kk * n + j];
                    }
                    want[i * n + j] = s;
                }
            }
            for (g, w) in got.iter().zip(want.iter()) {
                assert!((g - w).abs() < 1e-4, "({m},{k},{n}): {g} vs {w}");
            }
        }
    }

    #[test]
    fn packed_pads_to_lanes_and_preserves_rows() {
        // n = 5 pads to 8, with zeros beyond column 5
        let raw: Vec<f32> = (0..2 * 3 * 5).map(|x| x as f32).collect();
        let p = PackedMat::pack(&raw, 2, 3, 5);
        assert_eq!(p.n_pad, 8);
        let e1 = p.expert(1);
        assert_eq!(e1.len(), 3 * 8);
        assert_eq!(e1[0], raw[3 * 5]);
        assert_eq!(&e1[5..8], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn matmul_packed_matches_dense_with_padding() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (6usize, 7usize, 5usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gaussian() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.gaussian() as f32).collect();
        let p = PackedMat::pack(&b, 1, k, n);
        let mut out = vec![1.0f32; m * p.n_pad]; // dirty: kernel must overwrite
        matmul_packed(&a, k, p.expert(0), k, p.n_pad, m, &mut out);
        let want = matmul(&a, &b, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let (g, w) = (out[i * p.n_pad + j], want[i * n + j]);
                assert!((g - w).abs() < 1e-5, "[{i},{j}] {g} vs {w}");
            }
            for j in n..p.n_pad {
                assert_eq!(out[i * p.n_pad + j], 0.0, "pad column leaked");
            }
        }
    }

    #[test]
    fn silu_mul_fuses_activation() {
        let mut g = vec![-1.0f32, 0.0, 2.0];
        let u = vec![3.0f32, 5.0, -1.5];
        let want: Vec<f32> = g.iter().zip(u.iter()).map(|(&gv, &uv)| silu(gv) * uv).collect();
        silu_mul(&mut g, &u);
        assert_eq!(g, want);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.iter().all(|&p| p > 0.0));
        }
    }

    #[test]
    fn rmsnorm_unit_scale_unit_rows() {
        let h = vec![3.0f32, 4.0, 0.0, 0.0];
        let scale = vec![1.0f32; 4];
        let out = rmsnorm(&h, &scale, 4, 0.0);
        let ms: f32 = out.iter().map(|&x| x * x).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rope_at_pos_zero_is_identity() {
        let orig = vec![0.5f32, -1.0, 2.0, 0.25];
        let mut x = orig.clone();
        rope(&mut x, 1, 4, &[0], 10000.0);
        for (a, b) in x.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rope_preserves_pair_norm() {
        let mut x = vec![0.5f32, -1.0, 2.0, 0.25];
        let norm0: f32 = x.iter().map(|v| v * v).sum();
        rope(&mut x, 1, 4, &[17], 10000.0);
        let norm1: f32 = x.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() < 1e-4);
    }

    #[test]
    fn attention_single_visible_token_copies_v() {
        // pos = 0: only cache slot 0 visible, attention output == v[0]
        let (b, s, hq, hkv, hd) = (1, 4, 2, 1, 2);
        let q = vec![0.3f32; hq * hd];
        let mut k = vec![0.0f32; s * hkv * hd];
        let mut v = vec![0.0f32; s * hkv * hd];
        k[0] = 1.0;
        v[0] = 5.0;
        v[1] = -2.0;
        let out = decode_attention(&q, &k, &v, b, s, hq, hkv, hd, &[0]);
        for h in 0..hq {
            assert!((out[h * hd] - 5.0).abs() < 1e-6);
            assert!((out[h * hd + 1] + 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn attention_row_spans_compose() {
        // computing rows [0,2) and [2,4) separately must equal the whole
        let (b, s, hq, hkv, hd) = (4usize, 6usize, 2usize, 1usize, 4usize);
        let mut rng = Rng::new(9);
        let q: Vec<f32> = (0..b * hq * hd).map(|_| rng.gaussian() as f32).collect();
        let k: Vec<f32> = (0..b * s * hkv * hd).map(|_| rng.gaussian() as f32).collect();
        let v: Vec<f32> = (0..b * s * hkv * hd).map(|_| rng.gaussian() as f32).collect();
        let pos = vec![3i32, 0, 5, 2];
        let whole = decode_attention(&q, &k, &v, b, s, hq, hkv, hd, &pos);
        let mut parts = vec![0.0f32; b * hq * hd];
        let mut logits = vec![0.0f32; s];
        let half = 2 * hq * hd;
        {
            let (lo, hi) = parts.split_at_mut(half);
            decode_attention_rows(&q, &k, &v, s, hq, hkv, hd, &pos, 0, lo, &mut logits);
            decode_attention_rows(&q, &k, &v, s, hq, hkv, hd, &pos, 2, hi, &mut logits);
        }
        assert_eq!(whole, parts);
    }

    #[test]
    fn moe_padding_id_contributes_nothing() {
        let (b, d, h, n) = (2, 3, 4, 3);
        let x = vec![0.2f32; b * d];
        let wg = vec![0.1f32; n * d * h];
        let wu = vec![0.1f32; n * d * h];
        let wd = vec![0.1f32; n * h * d];
        // only expert 0 has combine mass
        let mut comb = vec![0.0f32; b * n];
        comb[0] = 1.0;
        comb[n] = 1.0;
        let a = moe_ffn_gather(&x, &wg, &wu, &wd, &comb, &[0], b, d, h, n);
        let bb = moe_ffn_gather(&x, &wg, &wu, &wd, &comb, &[0, 2, 2], b, d, h, n);
        for (x1, x2) in a.iter().zip(bb.iter()) {
            assert!((x1 - x2).abs() < 1e-6);
        }
    }

    #[test]
    fn grouped_ffn_matches_gather_oracle() {
        let (b, d, h, n) = (5usize, 8usize, 6usize, 4usize);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..b * d).map(|_| rng.gaussian() as f32).collect();
        let wg: Vec<f32> = (0..n * d * h).map(|_| rng.gaussian() as f32 * 0.2).collect();
        let wu: Vec<f32> = (0..n * d * h).map(|_| rng.gaussian() as f32 * 0.2).collect();
        let wd: Vec<f32> = (0..n * h * d).map(|_| rng.gaussian() as f32 * 0.2).collect();
        // random-ish sparse combine (some zero rows / zero entries)
        let mut comb = vec![0.0f32; b * n];
        for i in 0..b {
            for e in 0..n {
                if (i + e) % 3 != 0 {
                    comb[i * n + e] = 0.1 + ((i * n + e) % 7) as f32 * 0.1;
                }
            }
        }
        let ids: Vec<i32> = (0..n as i32).collect();
        let want = moe_ffn_gather(&x, &wg, &wu, &wd, &comb, &ids, b, d, h, n);
        let pg = PackedMat::pack(&wg, n, d, h);
        let pu = PackedMat::pack(&wu, n, d, h);
        let pd = PackedMat::pack(&wd, n, h, d);
        let groups = ExpertGroups::from_combine(&comb, &ids, b, n);
        let mut acc = vec![0.0f32; b * d];
        let mut arena = Arena::new();
        moe_ffn_groups(&x, &pg, &pu, &pd, 0, &groups, 0, groups.len(), &mut acc, &mut arena);
        for (i, (g, w)) in acc.iter().zip(want.iter()).enumerate() {
            assert!((g - w).abs() < 1e-5, "[{i}] grouped {g} vs gather {w}");
        }
        // split ranges (the parallel chunking) must also agree
        let mut acc2 = vec![0.0f32; b * d];
        let mid = groups.len() / 2;
        moe_ffn_groups(&x, &pg, &pu, &pd, 0, &groups, 0, mid, &mut acc2, &mut arena);
        moe_ffn_groups(&x, &pg, &pu, &pd, 0, &groups, mid, groups.len(), &mut acc2, &mut arena);
        assert_eq!(acc, acc2);
    }

    #[test]
    fn grouped_ffn_skips_unrouted_tokens() {
        // a token with zero combine everywhere must not affect any output
        let (b, d, h, n) = (3usize, 4usize, 4usize, 2usize);
        let x = vec![0.5f32; b * d];
        let wg = vec![0.1f32; n * d * h];
        let wu = vec![0.2f32; n * d * h];
        let wd = vec![0.3f32; n * h * d];
        let mut comb = vec![0.0f32; b * n];
        comb[0] = 1.0; // token 0 -> expert 0; tokens 1,2 unrouted
        let pg = PackedMat::pack(&wg, n, d, h);
        let pu = PackedMat::pack(&wu, n, d, h);
        let pd = PackedMat::pack(&wd, n, h, d);
        let groups = ExpertGroups::from_combine(&comb, &[0, 1], b, n);
        assert_eq!(groups.routed_tokens(), 1);
        let mut acc = vec![0.0f32; b * d];
        let mut arena = Arena::new();
        moe_ffn_groups(&x, &pg, &pu, &pd, 0, &groups, 0, groups.len(), &mut acc, &mut arena);
        assert!(acc[..d].iter().all(|&v| v != 0.0));
        assert!(acc[d..].iter().all(|&v| v == 0.0), "unrouted rows touched");
    }

    #[test]
    fn grouped_ffn_from_decision_route() {
        // end-to-end through a routing decision, per-expert order stable
        let scores = vec![
            0.6, 0.3, 0.1, //
            0.2, 0.5, 0.3, //
        ];
        let s = ScoreMatrix::new(2, 3, scores);
        let live = vec![true; 2];
        let d_route = route(
            Policy::Vanilla { k: 2 },
            &RoutingInput::new(&s, &live, true),
        );
        let groups = ExpertGroups::from_decision(&d_route);
        assert_eq!(groups.routed_tokens(), 4);
        let (b, d, h, n) = (2usize, 4usize, 4usize, 3usize);
        let mut rng = Rng::new(21);
        let x: Vec<f32> = (0..b * d).map(|_| rng.gaussian() as f32).collect();
        let wg: Vec<f32> = (0..n * d * h).map(|_| rng.gaussian() as f32 * 0.3).collect();
        let wu: Vec<f32> = (0..n * d * h).map(|_| rng.gaussian() as f32 * 0.3).collect();
        let wd: Vec<f32> = (0..n * h * d).map(|_| rng.gaussian() as f32 * 0.3).collect();
        let ids: Vec<i32> = d_route.active.iter().map(|&e| e as i32).collect();
        let want = moe_ffn_gather(&x, &wg, &wu, &wd, &d_route.combine, &ids, b, d, h, n);
        let pg = PackedMat::pack(&wg, n, d, h);
        let pu = PackedMat::pack(&wu, n, d, h);
        let pd = PackedMat::pack(&wd, n, h, d);
        let mut acc = vec![0.0f32; b * d];
        let mut arena = Arena::new();
        moe_ffn_groups(&x, &pg, &pu, &pd, 0, &groups, 0, groups.len(), &mut acc, &mut arena);
        for (g, w) in acc.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-5);
        }
    }
}
