//! Pure-Rust reference kernels mirroring `python/compile/kernels/ref.py`
//! (the cross-language correctness ground truth). All math is f32, plain
//! loops ordered for cache locality — fast enough for tests and the CI
//! bench-smoke tier; golden fixtures in `rust/tests/cpu_backend_golden.rs`
//! pin these against the JAX oracles.

/// `out[m, n] = a[m, k] @ b[k, n]` (row-major, ikj order so the inner loop
/// streams both `b` and `out`).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

/// RMSNorm per row: `h / sqrt(mean(h^2) + eps) * scale` (ref.rmsnorm_ref).
pub fn rmsnorm(h: &[f32], scale: &[f32], d: usize, eps: f32) -> Vec<f32> {
    debug_assert_eq!(h.len() % d, 0);
    debug_assert_eq!(scale.len(), d);
    let mut out = vec![0.0f32; h.len()];
    for (row, orow) in h.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms: f32 = row.iter().map(|&x| x * x).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for ((o, &x), &s) in orow.iter_mut().zip(row.iter()).zip(scale.iter()) {
            *o = x * inv * s;
        }
    }
    out
}

/// Numerically-stable softmax over each row of `x [rows, n]`, in place.
pub fn softmax_rows(x: &mut [f32], n: usize) {
    debug_assert_eq!(x.len() % n, 0);
    for row in x.chunks_exact_mut(n) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Router scores: `softmax(rmsnorm(h, n2) @ w)` (ref.router_scores_ref).
pub fn router_scores(
    h: &[f32],
    n2: &[f32],
    w: &[f32],
    b: usize,
    d: usize,
    n_experts: usize,
    eps: f32,
) -> Vec<f32> {
    let hn = rmsnorm(h, n2, d, eps);
    let mut s = matmul(&hn, w, b, d, n_experts);
    softmax_rows(&mut s, n_experts);
    s
}

/// RoPE over `x [rows, heads, hd]` with per-row positions, pairing
/// `(i, i + hd/2)` exactly like `model.rope`.
pub fn rope(x: &mut [f32], heads: usize, hd: usize, pos: &[i32], theta: f32) {
    let half = hd / 2;
    debug_assert_eq!(x.len(), pos.len() * heads * hd);
    for (r, &p) in pos.iter().enumerate() {
        for hh in 0..heads {
            let base = (r * heads + hh) * hd;
            for i in 0..half {
                let freq = theta.powf(-(i as f32) / half as f32);
                let ang = p as f32 * freq;
                let (sin, cos) = ang.sin_cos();
                let x1 = x[base + i];
                let x2 = x[base + half + i];
                x[base + i] = x1 * cos - x2 * sin;
                x[base + half + i] = x1 * sin + x2 * cos;
            }
        }
    }
}

/// Decode attention over the slot-stable cache (ref.decode_attention_ref):
/// GQA with `n_rep = Hq / Hkv`, causal mask `s <= pos[row]`, softmax over
/// the visible prefix. `k_cache`/`v_cache` are `[B, S, Hkv, hd]` slices of
/// the combined layer cache. Returns `[B, Hq, hd]`.
#[allow(clippy::too_many_arguments)]
pub fn decode_attention(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    b: usize,
    s_max: usize,
    hq: usize,
    hkv: usize,
    hd: usize,
    pos: &[i32],
) -> Vec<f32> {
    debug_assert_eq!(q.len(), b * hq * hd);
    debug_assert_eq!(k_cache.len(), b * s_max * hkv * hd);
    let n_rep = hq / hkv;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0.0f32; b * hq * hd];
    let mut logits = vec![0.0f32; s_max];
    for i in 0..b {
        let visible = (pos[i].max(0) as usize + 1).min(s_max);
        for h in 0..hq {
            let kvh = h / n_rep;
            let qrow = &q[(i * hq + h) * hd..(i * hq + h + 1) * hd];
            for (s, l) in logits[..visible].iter_mut().enumerate() {
                let krow = &k_cache[((i * s_max + s) * hkv + kvh) * hd..][..hd];
                let mut dot = 0.0f32;
                for (qv, kv) in qrow.iter().zip(krow.iter()) {
                    dot += qv * kv;
                }
                *l = dot * scale;
            }
            softmax_rows(&mut logits[..visible], visible);
            let orow = &mut out[(i * hq + h) * hd..(i * hq + h + 1) * hd];
            for (s, &p) in logits[..visible].iter().enumerate() {
                let vrow = &v_cache[((i * s_max + s) * hkv + kvh) * hd..][..hd];
                for (o, &vv) in orow.iter_mut().zip(vrow.iter()) {
                    *o += p * vv;
                }
            }
        }
    }
    out
}

/// Gather-based grouped expert FFN (ref.moe_ffn_gathered): iterate the
/// padded active list, `out += comb[:, e] * (silu(x@wg[e]) * (x@wu[e])) @
/// wd[e]`. Zero-combine padding ids contribute nothing but still run their
/// GEMMs — the measured work is proportional to `ids.len()` (the executed
/// T bucket), exactly like the gathered device kernel. `x` is the
/// already-normed input `[B, D]`; returns the FFN output `[B, D]` (the
/// caller adds the residual).
#[allow(clippy::too_many_arguments)]
pub fn moe_ffn_gather(
    x: &[f32],
    wg: &[f32],
    wu: &[f32],
    wd: &[f32],
    comb: &[f32],
    ids: &[i32],
    b: usize,
    d: usize,
    h: usize,
    n_experts: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), b * d);
    debug_assert_eq!(comb.len(), b * n_experts);
    let mut out = vec![0.0f32; b * d];
    for &id in ids {
        let e = id as usize;
        debug_assert!(e < n_experts);
        let wg_e = &wg[e * d * h..(e + 1) * d * h];
        let wu_e = &wu[e * d * h..(e + 1) * d * h];
        let wd_e = &wd[e * h * d..(e + 1) * h * d];
        let g = matmul(x, wg_e, b, d, h);
        let u = matmul(x, wu_e, b, d, h);
        let mut act = vec![0.0f32; b * h];
        for ((a, &gv), &uv) in act.iter_mut().zip(g.iter()).zip(u.iter()) {
            *a = silu(gv) * uv;
        }
        let y = matmul(&act, wd_e, b, h, d);
        for i in 0..b {
            let c = comb[i * n_experts + e];
            if c == 0.0 {
                continue;
            }
            let orow = &mut out[i * d..(i + 1) * d];
            let yrow = &y[i * d..(i + 1) * d];
            for (o, &yv) in orow.iter_mut().zip(yrow.iter()) {
                *o += c * yv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let id = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &id, 2, 2, 2), a);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.iter().all(|&p| p > 0.0));
        }
    }

    #[test]
    fn rmsnorm_unit_scale_unit_rows() {
        let h = vec![3.0f32, 4.0, 0.0, 0.0];
        let scale = vec![1.0f32; 4];
        let out = rmsnorm(&h, &scale, 4, 0.0);
        let ms: f32 = out.iter().map(|&x| x * x).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rope_at_pos_zero_is_identity() {
        let orig = vec![0.5f32, -1.0, 2.0, 0.25];
        let mut x = orig.clone();
        rope(&mut x, 1, 4, &[0], 10000.0);
        for (a, b) in x.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rope_preserves_pair_norm() {
        let mut x = vec![0.5f32, -1.0, 2.0, 0.25];
        let norm0: f32 = x.iter().map(|v| v * v).sum();
        rope(&mut x, 1, 4, &[17], 10000.0);
        let norm1: f32 = x.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() < 1e-4);
    }

    #[test]
    fn attention_single_visible_token_copies_v() {
        // pos = 0: only cache slot 0 visible, attention output == v[0]
        let (b, s, hq, hkv, hd) = (1, 4, 2, 1, 2);
        let q = vec![0.3f32; hq * hd];
        let mut k = vec![0.0f32; s * hkv * hd];
        let mut v = vec![0.0f32; s * hkv * hd];
        k[0] = 1.0;
        v[0] = 5.0;
        v[1] = -2.0;
        let out = decode_attention(&q, &k, &v, b, s, hq, hkv, hd, &[0]);
        for h in 0..hq {
            assert!((out[h * hd] - 5.0).abs() < 1e-6);
            assert!((out[h * hd + 1] + 2.0).abs() < 1e-6);
        }
    }

    #[test]
    fn moe_padding_id_contributes_nothing() {
        let (b, d, h, n) = (2, 3, 4, 3);
        let x = vec![0.2f32; b * d];
        let wg = vec![0.1f32; n * d * h];
        let wu = vec![0.1f32; n * d * h];
        let wd = vec![0.1f32; n * h * d];
        // only expert 0 has combine mass
        let mut comb = vec![0.0f32; b * n];
        comb[0] = 1.0;
        comb[n] = 1.0;
        let a = moe_ffn_gather(&x, &wg, &wu, &wd, &comb, &[0], b, d, h, n);
        let bb = moe_ffn_gather(&x, &wg, &wu, &wd, &comb, &[0, 2, 2], b, d, h, n);
        for (x1, x2) in a.iter().zip(bb.iter()) {
            assert!((x1 - x2).abs() < 1e-6);
        }
    }
}
