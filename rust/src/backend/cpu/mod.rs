//! Hermetic pure-Rust CPU reference backend.
//!
//! Mirrors the JAX model (`python/compile/model.py`) stage for stage using
//! the reference kernels in [`kernels`]: embed, RoPE decode attention over
//! the slot-stable KV cache, router score computation, and the
//! gather-based grouped expert FFN with per-expert load accounting.
//!
//! Weights come from [`CpuBackend::synthetic`], the Rust port of
//! `python/compile/weights.py`: seeded-random with *structure* — token
//! embeddings carry a domain component and router columns carry per-expert
//! domain affinities — so router softmax distributions have realistic
//! concentration and domain-correlated expert choice, the two properties
//! OEA's phases interact with. Quality is always measured relative to
//! vanilla routing of the same model, exactly the quantity the paper
//! sweeps, so no pretrained checkpoint is needed.

pub mod kernels;

use std::cell::RefCell;

use crate::backend::{Backend, LayerPre, Prefilled};
use crate::config::ModelConfig;
use crate::moe::policy::{self, Policy, RoutingInput};
use crate::moe::ScoreMatrix;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// One transformer layer's weights (shapes as in `weights.py`).
pub struct LayerWeights {
    /// `[D, Hq*hd]`
    pub wq: Vec<f32>,
    /// `[D, Hkv*hd]`
    pub wk: Vec<f32>,
    /// `[D, Hkv*hd]`
    pub wv: Vec<f32>,
    /// `[Hq*hd, D]`
    pub wo: Vec<f32>,
    /// `[D]`
    pub n1: Vec<f32>,
    /// `[D]`
    pub n2: Vec<f32>,
    /// `[D, N]`
    pub router: Vec<f32>,
    /// `[N, D, H]`
    pub wg: Vec<f32>,
    /// `[N, D, H]`
    pub wu: Vec<f32>,
    /// `[N, H, D]`
    pub wd: Vec<f32>,
}

/// Per-layer KV cache of a decode batch: `[2, bucket, S, Hkv, hd]` per
/// layer (K at index 0, V at index 1 — the PJRT layout, so repack logic
/// and tests transfer unchanged).
pub struct CpuKvCache {
    pub bucket: usize,
    pub layers: Vec<Vec<f32>>,
}

/// A prefilled sequence's per-layer KV rows, each `[S, Hkv, hd]`.
pub struct CpuKvRows {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

pub struct CpuBackend {
    cfg: ModelConfig,
    /// `[V, D]`
    pub embed_w: Vec<f32>,
    /// `[D, V]`
    pub unembed_w: Vec<f32>,
    /// `[D]`
    pub final_norm: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    /// Cumulative token-expert assignments per expert id (telemetry for
    /// load-balance analysis; counts decode and prefill work alike).
    expert_load: RefCell<Vec<u64>>,
}

fn gauss(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32).collect()
}

fn scaled(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
}

impl CpuBackend {
    /// Structured synthetic weights (the Rust port of `weights.py::init`).
    /// Deterministic in `(cfg, seed)`.
    pub fn synthetic(cfg: ModelConfig, seed: u64) -> CpuBackend {
        let mut rng = Rng::new(seed ^ 0x5EED_CAFE_F00D);
        let (d, v, n, h) = (cfg.d_model, cfg.vocab, cfg.n_experts, cfg.d_expert);
        let (qd, kvd, nd) = (cfg.q_dim(), cfg.kv_dim(), cfg.n_domains);

        // unit-norm domain centers in embedding space
        let mut centers = gauss(&mut rng, nd * d);
        for c in centers.chunks_exact_mut(d) {
            let norm = c.iter().map(|&x| x * x).sum::<f32>().sqrt().max(1e-6);
            for x in c.iter_mut() {
                *x /= norm;
            }
        }

        // embedding: domain component (band-structured token->domain
        // affinity, the offline stand-in for corpus co-occurrence) + noise,
        // unit-RMS rows
        let mut embed_w = scaled(&mut rng, v * d, 0.5);
        for (t, row) in embed_w.chunks_exact_mut(d).enumerate() {
            let primary = if t < 3 || v <= 3 {
                None
            } else {
                Some(((t - 3) * nd / (v - 3)).min(nd - 1))
            };
            for (dom, center) in centers.chunks_exact(d).enumerate() {
                let aff = match primary {
                    Some(p) if p == dom => 0.7,
                    Some(_) => 0.3 / (nd.max(2) - 1) as f32,
                    None => 1.0 / nd as f32,
                };
                for (x, &c) in row.iter_mut().zip(center.iter()) {
                    *x += aff * c;
                }
            }
            let ms = row.iter().map(|&x| x * x).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms.sqrt() + 1e-6);
            for x in row.iter_mut() {
                *x *= inv;
            }
        }

        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        let unembed_w = scaled(&mut rng, d * v, inv_sqrt_d);
        let final_norm = vec![1.0f32; d];

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            // expert -> domain assignment: round-robin, shuffled
            let mut dom: Vec<usize> = (0..n).map(|e| e % nd).collect();
            rng.shuffle(&mut dom);
            // router: per-expert domain affinity + idiosyncratic component
            let (beta, gamma) = (2.0 * inv_sqrt_d, inv_sqrt_d);
            let mut router = vec![0.0f32; d * n];
            for (e, &de) in dom.iter().enumerate() {
                let center = &centers[de * d..(de + 1) * d];
                for (dd, &c) in center.iter().enumerate() {
                    router[dd * n + e] = beta * c + gamma * rng.gaussian() as f32;
                }
            }
            layers.push(LayerWeights {
                wq: scaled(&mut rng, d * qd, inv_sqrt_d),
                wk: scaled(&mut rng, d * kvd, inv_sqrt_d),
                wv: scaled(&mut rng, d * kvd, inv_sqrt_d),
                wo: scaled(&mut rng, qd * d, 0.5 / (qd as f32).sqrt()),
                n1: vec![1.0f32; d],
                n2: vec![1.0f32; d],
                router,
                wg: scaled(&mut rng, n * d * h, inv_sqrt_d),
                wu: scaled(&mut rng, n * d * h, inv_sqrt_d),
                wd: scaled(&mut rng, n * h * d, 0.5 / (h as f32).sqrt()),
            });
        }

        CpuBackend {
            expert_load: RefCell::new(vec![0u64; n]),
            cfg,
            embed_w,
            unembed_w,
            final_norm,
            layers,
        }
    }

    /// Snapshot of cumulative per-expert token assignments.
    pub fn expert_loads(&self) -> Vec<u64> {
        self.expert_load.borrow().clone()
    }

    pub fn reset_expert_loads(&self) {
        for x in self.expert_load.borrow_mut().iter_mut() {
            *x = 0;
        }
    }

    /// `S * Hkv * hd` — one slot's cache row length.
    fn row_len(&self) -> usize {
        self.cfg.s_max * self.cfg.n_kv_heads * self.cfg.head_dim
    }
}

impl Backend for CpuBackend {
    type Cache = CpuKvCache;
    type Rows = CpuKvRows;

    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn label(&self) -> &'static str {
        "cpu"
    }

    fn new_cache(&self, bucket: usize) -> Result<CpuKvCache> {
        let layers = (0..self.cfg.n_layers)
            .map(|_| vec![0.0f32; 2 * bucket * self.row_len()])
            .collect();
        Ok(CpuKvCache { bucket, layers })
    }

    fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (d, v) = (self.cfg.d_model, self.cfg.vocab);
        let mut out = vec![0.0f32; tokens.len() * d];
        for (i, &t) in tokens.iter().enumerate() {
            // clamp like jnp.take's default out-of-bounds behaviour
            let t = (t.max(0) as usize).min(v - 1);
            out[i * d..(i + 1) * d].copy_from_slice(&self.embed_w[t * d..(t + 1) * d]);
        }
        Ok(out)
    }

    fn layer_pre(
        &self,
        l: usize,
        hidden: &[f32],
        cache: &mut CpuKvCache,
        pos: &[i32],
    ) -> Result<LayerPre> {
        let c = &self.cfg;
        let b = pos.len();
        if hidden.len() != b * c.d_model || cache.bucket != b {
            return Err(Error::Engine(format!(
                "layer_pre shape mismatch: hidden {} pos {} bucket {}",
                hidden.len(),
                b,
                cache.bucket
            )));
        }
        let lw = &self.layers[l];
        let (d, qd, kvd) = (c.d_model, c.q_dim(), c.kv_dim());
        let (hq, hkv, hd) = (c.n_q_heads, c.n_kv_heads, c.head_dim);

        let h1 = kernels::rmsnorm(hidden, &lw.n1, d, c.rms_eps);
        let mut q = kernels::matmul(&h1, &lw.wq, b, d, qd);
        let mut k = kernels::matmul(&h1, &lw.wk, b, d, kvd);
        let v = kernels::matmul(&h1, &lw.wv, b, d, kvd);
        kernels::rope(&mut q, hq, hd, pos, c.rope_theta);
        kernels::rope(&mut k, hkv, hd, pos, c.rope_theta);

        // slot-stable cache append: row b's slot pos[b] gets this step's K/V
        let row = self.row_len();
        let half = b * row;
        let cl = &mut cache.layers[l];
        for i in 0..b {
            let slot = (pos[i].max(0) as usize).min(c.s_max - 1);
            let dst = i * row + slot * kvd;
            cl[dst..dst + kvd].copy_from_slice(&k[i * kvd..(i + 1) * kvd]);
            cl[half + dst..half + dst + kvd].copy_from_slice(&v[i * kvd..(i + 1) * kvd]);
        }

        // attention over the UPDATED cache (model.py layer_pre semantics)
        let (kc, vc) = cl.split_at(half);
        let attn = kernels::decode_attention(&q, kc, vc, b, c.s_max, hq, hkv, hd, pos);
        let ao = kernels::matmul(&attn, &lw.wo, b, qd, d);
        let mut h_out = hidden.to_vec();
        for (o, &a) in h_out.iter_mut().zip(ao.iter()) {
            *o += a;
        }
        let scores =
            kernels::router_scores(&h_out, &lw.n2, &lw.router, b, d, c.n_experts, c.rms_eps);
        Ok(LayerPre { h: h_out, scores })
    }

    fn moe_apply(
        &self,
        l: usize,
        hidden: &[f32],
        combine: &[f32],
        ids: &[i32],
    ) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let (d, h, n) = (c.d_model, c.d_expert, c.n_experts);
        let b = hidden.len() / d;
        if combine.len() != b * n {
            return Err(Error::Engine(format!(
                "moe_apply combine len {} != {}x{}",
                combine.len(),
                b,
                n
            )));
        }
        for &id in ids {
            if id < 0 || id as usize >= n {
                return Err(Error::Engine(format!("moe_apply expert id {id} out of range")));
            }
        }
        let lw = &self.layers[l];
        let hn = kernels::rmsnorm(hidden, &lw.n2, d, c.rms_eps);
        let y = kernels::moe_ffn_gather(&hn, &lw.wg, &lw.wu, &lw.wd, combine, ids, b, d, h, n);
        {
            let mut load = self.expert_load.borrow_mut();
            for rowc in combine.chunks_exact(n) {
                for (e, &cv) in rowc.iter().enumerate() {
                    if cv > 0.0 {
                        load[e] += 1;
                    }
                }
            }
        }
        let mut out = hidden.to_vec();
        for (o, &yv) in out.iter_mut().zip(y.iter()) {
            *o += yv;
        }
        Ok(out)
    }

    fn logits(&self, hidden: &[f32]) -> Result<Vec<f32>> {
        let (d, v) = (self.cfg.d_model, self.cfg.vocab);
        let b = hidden.len() / d;
        let hn = kernels::rmsnorm(hidden, &self.final_norm, d, self.cfg.rms_eps);
        Ok(kernels::matmul(&hn, &self.unembed_w, b, d, v))
    }

    /// Teacher-forced prefill: the prompt runs through the decode path one
    /// token at a time with in-graph vanilla routing, which is *exactly*
    /// the decode pipeline's math — prefill/decode consistency holds by
    /// construction (the chunked-prefill fast path is a PJRT artifact
    /// concern; the reference backend favours exactness).
    fn prefill(&self, prompt: &[i32]) -> Result<Prefilled<CpuKvRows>> {
        let c = self.cfg.clone();
        if prompt.is_empty() {
            return Err(Error::Engine("empty prompt".into()));
        }
        if prompt.len() > c.s_max - 1 {
            return Err(Error::Engine(format!(
                "prompt of {} tokens exceeds s_max-1 = {}",
                prompt.len(),
                c.s_max - 1
            )));
        }
        let mut cache = self.new_cache(1)?;
        let mut last_hidden = Vec::new();
        for (t, &tok) in prompt.iter().enumerate() {
            let mut hidden = self.embed(&[tok])?;
            for l in 0..c.n_layers {
                let pre = self.layer_pre(l, &hidden, &mut cache, &[t as i32])?;
                let scores = ScoreMatrix::new(1, c.n_experts, pre.scores);
                let live = [true];
                let d = policy::route(
                    Policy::Vanilla { k: c.top_k },
                    &RoutingInput { scores: &scores, live: &live, mask_padding: true },
                );
                let ids: Vec<i32> = d.active.iter().map(|&e| e as i32).collect();
                hidden = self.moe_apply(l, &pre.h, &d.combine, &ids)?;
            }
            last_hidden = hidden;
        }
        let last_logits = self.logits(&last_hidden)?;
        let row = self.row_len();
        let mut k_rows = Vec::with_capacity(c.n_layers);
        let mut v_rows = Vec::with_capacity(c.n_layers);
        for cl in &cache.layers {
            k_rows.push(cl[..row].to_vec());
            v_rows.push(cl[row..2 * row].to_vec());
        }
        Ok(Prefilled {
            rows: CpuKvRows { k: k_rows, v: v_rows },
            n_tokens: prompt.len(),
            last_logits,
        })
    }

    fn install_rows(&self, cache: &mut CpuKvCache, slot: usize, rows: &CpuKvRows) -> Result<()> {
        let row = self.row_len();
        let b = cache.bucket;
        if slot >= b {
            return Err(Error::Engine(format!("slot {slot} out of bucket {b}")));
        }
        for (l, cl) in cache.layers.iter_mut().enumerate() {
            let half = b * row;
            cl[slot * row..(slot + 1) * row].copy_from_slice(&rows.k[l]);
            cl[half + slot * row..half + (slot + 1) * row].copy_from_slice(&rows.v[l]);
        }
        Ok(())
    }

    fn clear_slot(&self, cache: &mut CpuKvCache, slot: usize) -> Result<()> {
        let row = self.row_len();
        let b = cache.bucket;
        if slot >= b {
            return Err(Error::Engine(format!("slot {slot} out of bucket {b}")));
        }
        for cl in cache.layers.iter_mut() {
            let half = b * row;
            cl[slot * row..(slot + 1) * row].fill(0.0);
            cl[half + slot * row..half + (slot + 1) * row].fill(0.0);
        }
        Ok(())
    }

    fn repack(
        &self,
        cache: &CpuKvCache,
        old_bucket: usize,
        new_bucket: usize,
        mapping: &[Option<usize>],
    ) -> Result<CpuKvCache> {
        if cache.bucket != old_bucket || mapping.len() != old_bucket {
            return Err(Error::Engine("repack mapping/bucket mismatch".into()));
        }
        let row = self.row_len();
        let mut out = self.new_cache(new_bucket)?;
        for (l, cl) in cache.layers.iter().enumerate() {
            let fresh = &mut out.layers[l];
            for half in 0..2 {
                let src_base = half * old_bucket * row;
                let dst_base = half * new_bucket * row;
                for (i, m) in mapping.iter().enumerate() {
                    if let Some(j) = m {
                        if *j >= new_bucket {
                            return Err(Error::Engine(format!(
                                "repack target slot {j} out of bucket {new_bucket}"
                            )));
                        }
                        fresh[dst_base + j * row..dst_base + (j + 1) * row]
                            .copy_from_slice(&cl[src_base + i * row..src_base + (i + 1) * row]);
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> CpuBackend {
        CpuBackend::synthetic(ModelConfig::preset("tiny").unwrap(), 0)
    }

    #[test]
    fn synthetic_weights_are_deterministic() {
        let a = backend();
        let b = backend();
        assert_eq!(a.embed_w, b.embed_w);
        assert_eq!(a.layers[0].router, b.layers[0].router);
        let c = CpuBackend::synthetic(ModelConfig::preset("tiny").unwrap(), 1);
        assert_ne!(a.embed_w, c.embed_w);
    }

    #[test]
    fn router_scores_have_realistic_concentration() {
        // top-1 mass dominant but well below 1 — the property the OEA
        // phases interact with (weights.py's stated calibration target)
        let be = backend();
        let c = be.config().clone();
        let mut cache = be.new_cache(4).unwrap();
        let h = be.embed(&[5, 100, 200, 400]).unwrap();
        let pre = be.layer_pre(0, &h, &mut cache, &[0, 0, 0, 0]).unwrap();
        for row in pre.scores.chunks_exact(c.n_experts) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "softmax rows sum to 1, got {sum}");
            let top1 = row.iter().cloned().fold(0.0f32, f32::max);
            assert!(top1 > 1.5 / c.n_experts as f32, "flat router (top1 {top1})");
            assert!(top1 < 0.99, "collapsed router (top1 {top1})");
        }
    }

    #[test]
    fn expert_load_accounting_counts_assignments() {
        let be = backend();
        let c = be.config().clone();
        let n = c.n_experts;
        let b = 2;
        let hidden = vec![0.1f32; b * c.d_model];
        let mut combine = vec![0.0f32; b * n];
        combine[0] = 0.6;
        combine[1] = 0.4;
        combine[n + 2] = 1.0;
        be.moe_apply(0, &hidden, &combine, &[0, 1, 2]).unwrap();
        let loads = be.expert_loads();
        assert_eq!(loads[0], 1);
        assert_eq!(loads[1], 1);
        assert_eq!(loads[2], 1);
        assert_eq!(loads.iter().sum::<u64>(), 3);
        be.reset_expert_loads();
        assert_eq!(be.expert_loads().iter().sum::<u64>(), 0);
    }

    #[test]
    fn moe_rejects_out_of_range_ids() {
        let be = backend();
        let c = be.config().clone();
        let hidden = vec![0.0f32; c.d_model];
        let combine = vec![0.0f32; c.n_experts];
        assert!(be.moe_apply(0, &hidden, &combine, &[c.n_experts as i32]).is_err());
    }
}
