//! Hermetic pure-Rust CPU backend.
//!
//! Mirrors the JAX model (`python/compile/model.py`) stage for stage using
//! the kernels in [`kernels`]: embed, RoPE decode attention over the
//! slot-stable KV cache, router score computation, and the expert FFN.
//!
//! The MoE stage runs in one of two dispatch modes
//! ([`DispatchMode`], a constructor flag):
//!
//! - **Grouped** (default): token-grouped expert dispatch — each active
//!   expert's routed rows are gathered into a contiguous mini-batch, run
//!   through pre-packed weight panels ([`kernels::PackedMat`]), and
//!   scatter-added back weighted by combine. Per-step work is
//!   `Σ_e |tokens(e)| · 3DH` (the routed load), expert groups and
//!   attention batch rows execute in parallel over a
//!   [`crate::util::threadpool::ThreadPool`], and all kernel scratch
//!   comes from reusable arenas ([`crate::util::arena`]) so the hot loop
//!   performs no per-step heap allocation once warm.
//! - **Gather**: the original gathered-kernel oracle — every listed
//!   expert runs full-batch GEMMs (`T_bucket · B · 3DH` work), matching
//!   the gathered device kernel's cost model. Kept as the golden-pinned
//!   correctness reference; the two modes agree within float tolerance
//!   (see `rust/tests/dispatch_equivalence.rs`).
//!
//! Weights come from [`CpuBackend::synthetic`], the Rust port of
//! `python/compile/weights.py`: seeded-random with *structure* — token
//! embeddings carry a domain component and router columns carry per-expert
//! domain affinities — so router softmax distributions have realistic
//! concentration and domain-correlated expert choice, the two properties
//! OEA's phases interact with. Quality is always measured relative to
//! vanilla routing of the same model, exactly the quantity the paper
//! sweeps, so no pretrained checkpoint is needed.

pub mod kernels;

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use crate::backend::{Backend, LayerPre, Prefilled};
use crate::config::ModelConfig;
use crate::faults::{FaultPlan, FaultState, FaultStats};
use crate::moe::dispatch::{ExpertGroups, RoutedStep};
use crate::moe::ep::{rank_of, rank_span};
use crate::moe::policy::{self, Policy, RoutingInput};
use crate::moe::ScoreMatrix;
use crate::obs::{Tracer, BACKEND_TID};
use crate::residency::{
    EvictPolicy, Prefetcher, ResidencyConfig, ResidencyCounters, ResidencySet, ResidencyStats,
    Touch,
};
use crate::util::arena::{with_thread_arena, Arena, ScratchPool};
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

use kernels::{KernelMode, PackedMat, PanelDtype};

/// How `moe_apply` executes the expert FFN. See the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchMode {
    /// Token-grouped dispatch (work ∝ routed load) — the fast default.
    #[default]
    Grouped,
    /// Full-batch gathered kernel (work ∝ T bucket × B) — the oracle.
    Gather,
}

/// Construction options for [`CpuBackend::synthetic_with`].
#[derive(Debug, Clone, Copy)]
pub struct CpuOptions {
    pub dispatch: DispatchMode,
    /// Worker threads for expert groups and attention rows: `0` = one
    /// per available core, `1` = run inline (no pool).
    pub threads: usize,
    /// Expert residency: manage each layer's packed panels as a bounded
    /// cache (capacity `C` experts, pluggable eviction, optional
    /// lookahead prefetch). `None` = every expert pre-packed at
    /// construction, the pre-residency behaviour. Grouped dispatch only.
    /// Under `ep_ranks > 1` the capacity splits evenly across ranks
    /// (`ceil(C / R)` per rank) and each rank evicts/prefetches within
    /// its own shard.
    pub residency: Option<ResidencyConfig>,
    /// Expert-parallel rank shards: packed expert panels split into
    /// `ep_ranks` contiguous blocks ([`crate::moe::ep::rank_of`]),
    /// grouped dispatch runs per-rank work lists (chunks never straddle
    /// a rank), and residency becomes per-rank. `1` = the single-rank
    /// path, bitwise-identical to the pre-EP backend. Grouped dispatch
    /// only.
    pub ep_ranks: usize,
    /// Kernel implementation for the hot paths
    /// ([`kernels::KernelMode`]): the scalar oracle by default (all
    /// bitwise pins hold), or runtime-detected AVX2+FMA SIMD. Requesting
    /// SIMD on a host without the features silently degrades to scalar
    /// (`kernels::simd_available`).
    pub kernels: KernelMode,
    /// Storage dtype of the packed expert panels
    /// ([`kernels::PanelDtype`]): f32 (default, bitwise-pinned), bf16
    /// (half the panel bytes), or int8 with per-row scales (~4× fewer
    /// bytes). Quantized panels require grouped dispatch — the gather
    /// oracle runs raw f32 weights.
    pub panel_dtype: PanelDtype,
}

impl Default for CpuOptions {
    fn default() -> Self {
        CpuOptions {
            dispatch: DispatchMode::Grouped,
            threads: 0,
            residency: None,
            ep_ranks: 1,
            kernels: KernelMode::Scalar,
            panel_dtype: PanelDtype::F32,
        }
    }
}

impl CpuOptions {
    /// Environment overrides for benches and A/B runs:
    /// `OEA_DISPATCH=grouped|gather`, `OEA_THREADS=<n>`,
    /// `OEA_KERNELS=scalar|simd`, `OEA_PANEL_DTYPE=f32|bf16|int8`.
    /// Panics on unrecognized values — a typo must not silently measure
    /// the wrong dispatch mode, kernel, or dtype.
    pub fn from_env() -> CpuOptions {
        let mut o = CpuOptions::default();
        if let Ok(v) = std::env::var("OEA_DISPATCH") {
            o.dispatch = match v.trim().to_ascii_lowercase().as_str() {
                "gather" => DispatchMode::Gather,
                "grouped" => DispatchMode::Grouped,
                other => panic!("OEA_DISPATCH={other:?}: expected grouped|gather"),
            };
        }
        if let Ok(v) = std::env::var("OEA_THREADS") {
            o.threads = v
                .trim()
                .parse::<usize>()
                .unwrap_or_else(|_| panic!("OEA_THREADS={v:?}: not an integer"));
        }
        if let Ok(v) = std::env::var("OEA_KERNELS") {
            o.kernels = match v.trim().to_ascii_lowercase().as_str() {
                "scalar" => KernelMode::Scalar,
                "simd" => KernelMode::Simd,
                other => panic!("OEA_KERNELS={other:?}: expected scalar|simd"),
            };
        }
        if let Ok(v) = std::env::var("OEA_PANEL_DTYPE") {
            o.panel_dtype = match v.trim().to_ascii_lowercase().as_str() {
                "f32" => PanelDtype::F32,
                "bf16" => PanelDtype::Bf16,
                "int8" => PanelDtype::Int8,
                other => panic!("OEA_PANEL_DTYPE={other:?}: expected f32|bf16|int8"),
            };
        }
        o
    }
}

/// One transformer layer's weights (shapes as in `weights.py`).
pub struct LayerWeights {
    /// `[D, Hq*hd]`
    pub wq: Vec<f32>,
    /// `[D, Hkv*hd]`
    pub wk: Vec<f32>,
    /// `[D, Hkv*hd]`
    pub wv: Vec<f32>,
    /// `[Hq*hd, D]`
    pub wo: Vec<f32>,
    /// `[D]`
    pub n1: Vec<f32>,
    /// `[D]`
    pub n2: Vec<f32>,
    /// `[D, N]`
    pub router: Vec<f32>,
    /// `[N, D, H]`
    pub wg: Vec<f32>,
    /// `[N, D, H]`
    pub wu: Vec<f32>,
    /// `[N, H, D]`
    pub wd: Vec<f32>,
}

/// One EP rank's contiguous expert-panel shard of one layer (grouped
/// mode without residency): experts `[e0, e0 + wg.experts)` packed
/// together. `ep_ranks = 1` is a single shard covering the whole layer —
/// the exact pre-EP pack. Per-expert panel rows are byte-identical
/// however the shard was cut, so sharded execution is bitwise-equal to
/// whole-layer execution (same guarantee `ExpertPanels::pack` documents
/// for residency paging).
struct PackedShard {
    /// first expert id of the shard
    e0: usize,
    wg: PackedMat,
    wu: PackedMat,
    wd: PackedMat,
}

/// One expert's packed SwiGLU panels — the unit of residency paging.
/// Behind an `Arc` so an in-flight step keeps executing an expert that a
/// later group's miss evicts (capacity thrash re-pages it next step).
pub struct ExpertPanels {
    wg: PackedMat,
    wu: PackedMat,
    wd: PackedMat,
}

impl ExpertPanels {
    /// Pack expert `e`'s three matrices out of the layer's raw weights —
    /// byte-identical to the corresponding rows of the whole-layer pack
    /// at the same dtype, which is what keeps residency execution
    /// bitwise-equal to the eager pack.
    fn pack(lw: &LayerWeights, e: usize, d: usize, h: usize, dtype: PanelDtype) -> ExpertPanels {
        ExpertPanels {
            wg: PackedMat::pack_dtype(&lw.wg[e * d * h..(e + 1) * d * h], 1, d, h, dtype),
            wu: PackedMat::pack_dtype(&lw.wu[e * d * h..(e + 1) * d * h], 1, d, h, dtype),
            wd: PackedMat::pack_dtype(&lw.wd[e * h * d..(e + 1) * h * d], 1, h, d, dtype),
        }
    }

    /// Packed footprint in bytes (the page-in size the ledger charges) —
    /// tracks the storage dtype, so quantized panels charge fewer bytes.
    fn bytes(&self) -> usize {
        self.wg.bytes() + self.wu.bytes() + self.wd.bytes()
    }
}

/// One (layer, rank) residency state: the rank's bounded set over its
/// expert shard (shard-local ids), its own lookahead prefetcher and
/// load-event counters, and the lazily-paged panels (`Some` iff resident,
/// so cold-start memory is only what was touched). Per-rank ownership is
/// what balances eviction and page-in traffic across ranks instead of
/// pooling it globally. At `ep_ranks = 1` a layer holds exactly one of
/// these covering every expert — the pre-EP behaviour, state for state.
struct RankResidency {
    /// first expert id of this rank's shard
    e0: usize,
    set: ResidencySet,
    prefetch: Prefetcher,
    counters: ResidencyCounters,
    /// shard-local: `panels[e - e0]`
    panels: Vec<Option<Arc<ExpertPanels>>>,
}

impl RankResidency {
    /// Page shard-local expert `le`'s panels in (packing them if absent)
    /// and charge this rank's ledger at the panel dtype's byte size.
    fn page_in(&mut self, lw: &LayerWeights, le: usize, d: usize, h: usize, dtype: PanelDtype) {
        let p = Arc::new(ExpertPanels::pack(lw, self.e0 + le, d, h, dtype));
        self.counters.bytes_paged += p.bytes() as u64;
        self.panels[le] = Some(p);
    }

    fn drop_panel(&mut self, le: usize) {
        self.counters.evictions += 1;
        self.panels[le] = None;
    }
}

/// One layer's residency: one [`RankResidency`] per EP rank.
struct LayerResidency {
    ranks: Vec<RankResidency>,
}

impl LayerResidency {
    fn new(n_experts: usize, cfg: &ResidencyConfig, ep_ranks: usize) -> LayerResidency {
        // capacity splits evenly across ranks; at ep_ranks = 1 this is
        // exactly the configured capacity
        let cap_r = cfg.capacity.div_ceil(ep_ranks);
        let ranks = (0..ep_ranks)
            .map(|r| {
                let (e0, e1) = rank_span(r, n_experts, ep_ranks);
                RankResidency {
                    e0,
                    set: ResidencySet::new(e1 - e0, cap_r, cfg.evict),
                    prefetch: Prefetcher::new(cfg.prefetch),
                    counters: ResidencyCounters::default(),
                    panels: (e0..e1).map(|_| None).collect(),
                }
            })
            .collect();
        LayerResidency { ranks }
    }
}

/// Per-layer KV cache of a decode batch: `[2, bucket, S, Hkv, hd]` per
/// layer (K at index 0, V at index 1 — the PJRT layout, so repack logic
/// and tests transfer unchanged).
pub struct CpuKvCache {
    pub bucket: usize,
    pub layers: Vec<Vec<f32>>,
}

/// A prefilled sequence's per-layer KV rows, each `[S, Hkv, hd]`.
pub struct CpuKvRows {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

pub struct CpuBackend {
    cfg: ModelConfig,
    /// `[V, D]`
    pub embed_w: Vec<f32>,
    /// `[D, V]`
    pub unembed_w: Vec<f32>,
    /// `[D]`
    pub final_norm: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    /// pre-transposed/padded expert panels, per layer × per EP rank
    /// shard (grouped mode without residency; empty when residency pages
    /// panels lazily). One shard per layer at `ep_ranks = 1`.
    packed: Vec<Vec<PackedShard>>,
    /// per-(layer, rank) expert residency (None = all panels pre-packed
    /// above)
    residency: Option<Mutex<Vec<LayerResidency>>>,
    res_cfg: Option<ResidencyConfig>,
    /// EP rank shards the MoE stage executes over (1 = single-rank)
    ep_ranks: usize,
    mode: DispatchMode,
    /// kernel implementation selected for the hot paths (scalar oracle
    /// by default; SIMD degrades to scalar on unsupported hosts)
    kernels_mode: KernelMode,
    /// storage dtype the expert panels were packed in
    panel_dtype: PanelDtype,
    /// worker pool for expert groups / attention rows (None = inline)
    pool: Option<ThreadPool>,
    /// Pinned per-rank worker pools (grouped dispatch, `ep_ranks > 1`,
    /// threaded): each EP rank's work list executes on its own subset of
    /// `workers / ep_ranks` threads driven by one scope thread per rank,
    /// so ranks genuinely overlap and per-rank wall time is measurable
    /// ([`CpuBackend::rank_wall`]) — the wall-clock counterpart of the
    /// cost model's analytic max-over-ranks step time. Empty = the
    /// single-pool path.
    rank_pools: Vec<ThreadPool>,
    /// wall-clock µs each EP rank spent in the most recent grouped MoE
    /// call (index = rank; empty until grouped dispatch has run)
    rank_wall: Mutex<Vec<f64>>,
    /// shared scratch for buffers that cross threads or live across one
    /// backend call (hidden-state temporaries, partial accumulators)
    scratch: ScratchPool,
    /// Cumulative routed (nonzero-combine) token-expert assignments per
    /// expert id (telemetry for load-balance analysis; counts decode and
    /// prefill work alike).
    expert_load: Mutex<Vec<u64>>,
    /// Deterministic fault-injection plane ([`crate::faults`]): installed
    /// post-construction via [`CpuBackend::install_faults`] (CpuOptions is
    /// `Copy`; a plan holds vectors), `None` = no faults, zero overhead on
    /// every hot path.
    faults: Option<Mutex<FaultState>>,
    /// Flight recorder ([`crate::obs`]): page-in / prefetch instants on
    /// the backend track. Installed post-construction via
    /// [`CpuBackend::install_tracer`]; `None` = no tracing code runs.
    tracer: Option<Arc<Tracer>>,
}

/// Lock that survives a mutex poisoned by an (injected or organic) panic:
/// the engine's `catch_unwind` recovery keeps serving after a step dies
/// mid-flight, and the state under these locks — counters, residency
/// ledgers, fault bookkeeping — stays internally consistent at every
/// point a panic can interrupt, so recovering the guard is safe where
/// propagating the poison would wedge every later request.
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn gauss(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32).collect()
}

fn scaled(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
}

/// Contiguous `(rank, g0, g1)` group ranges balanced by routed-row count
/// *within each rank's work list* — chunks never straddle a rank
/// boundary, so every chunk executes against exactly one panel shard and
/// per-rank work stays attributable. Ascending-expert order is preserved
/// (ranks are ascending id blocks), so chunked execution sums in the
/// same order as serial. At `ranks = 1` the boundaries are exactly the
/// pre-EP whole-list chunking.
fn chunk_groups(
    groups: &ExpertGroups,
    workers: usize,
    ranges: &[(usize, usize)],
) -> Vec<(usize, usize, usize)> {
    let mut out = Vec::with_capacity(workers.max(ranges.len()));
    for (r, &(r0, r1)) in ranges.iter().enumerate() {
        chunk_rank(groups, workers, r, r0, r1, &mut out);
    }
    out
}

/// One rank's slice of [`chunk_groups`]: split group range `[r0, r1)`
/// into up to `workers` row-balanced contiguous chunks, appended to
/// `out` in ascending order. The concurrent-rank path calls this per
/// rank (with that rank's pinned worker count) so each driver chunks
/// only its own work list.
fn chunk_rank(
    groups: &ExpertGroups,
    workers: usize,
    rank: usize,
    r0: usize,
    r1: usize,
    out: &mut Vec<(usize, usize, usize)>,
) {
    if r1 == r0 {
        return;
    }
    let rows: usize = (r0..r1).map(|gi| groups.group(gi).rows.len()).sum();
    let nchunks = workers.min(r1 - r0).max(1);
    let target = rows.div_ceil(nchunks).max(1);
    let mut start = r0;
    let mut acc = 0;
    for gi in r0..r1 {
        acc += groups.group(gi).rows.len();
        if acc >= target || gi == r1 - 1 {
            out.push((rank, start, gi + 1));
            start = gi + 1;
            acc = 0;
        }
    }
}

impl CpuBackend {
    /// Structured synthetic weights (the Rust port of `weights.py::init`)
    /// with default options: grouped dispatch, one worker per core.
    /// Deterministic in `(cfg, seed)` — the dispatch mode never changes
    /// the weights.
    pub fn synthetic(cfg: ModelConfig, seed: u64) -> CpuBackend {
        Self::synthetic_with(cfg, seed, CpuOptions::default())
    }

    /// Structured synthetic weights with explicit dispatch/threading
    /// options ([`CpuOptions`]).
    pub fn synthetic_with(cfg: ModelConfig, seed: u64, opts: CpuOptions) -> CpuBackend {
        let mut rng = Rng::new(seed ^ 0x5EED_CAFE_F00D);
        let (d, v, n, h) = (cfg.d_model, cfg.vocab, cfg.n_experts, cfg.d_expert);
        let (qd, kvd, nd) = (cfg.q_dim(), cfg.kv_dim(), cfg.n_domains);

        // unit-norm domain centers in embedding space
        let mut centers = gauss(&mut rng, nd * d);
        for c in centers.chunks_exact_mut(d) {
            let norm = c.iter().map(|&x| x * x).sum::<f32>().sqrt().max(1e-6);
            for x in c.iter_mut() {
                *x /= norm;
            }
        }

        // embedding: domain component (band-structured token->domain
        // affinity, the offline stand-in for corpus co-occurrence) + noise,
        // unit-RMS rows
        let mut embed_w = scaled(&mut rng, v * d, 0.5);
        for (t, row) in embed_w.chunks_exact_mut(d).enumerate() {
            let primary = if t < 3 || v <= 3 {
                None
            } else {
                Some(((t - 3) * nd / (v - 3)).min(nd - 1))
            };
            for (dom, center) in centers.chunks_exact(d).enumerate() {
                let aff = match primary {
                    Some(p) if p == dom => 0.7,
                    Some(_) => 0.3 / (nd.max(2) - 1) as f32,
                    None => 1.0 / nd as f32,
                };
                for (x, &c) in row.iter_mut().zip(center.iter()) {
                    *x += aff * c;
                }
            }
            let ms = row.iter().map(|&x| x * x).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms.sqrt() + 1e-6);
            for x in row.iter_mut() {
                *x *= inv;
            }
        }

        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        let unembed_w = scaled(&mut rng, d * v, inv_sqrt_d);
        let final_norm = vec![1.0f32; d];

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            // expert -> domain assignment: round-robin, shuffled
            let mut dom: Vec<usize> = (0..n).map(|e| e % nd).collect();
            rng.shuffle(&mut dom);
            // router: per-expert domain affinity + idiosyncratic component
            let (beta, gamma) = (2.0 * inv_sqrt_d, inv_sqrt_d);
            let mut router = vec![0.0f32; d * n];
            for (e, &de) in dom.iter().enumerate() {
                let center = &centers[de * d..(de + 1) * d];
                for (dd, &c) in center.iter().enumerate() {
                    router[dd * n + e] = beta * c + gamma * rng.gaussian() as f32;
                }
            }
            layers.push(LayerWeights {
                wq: scaled(&mut rng, d * qd, inv_sqrt_d),
                wk: scaled(&mut rng, d * kvd, inv_sqrt_d),
                wv: scaled(&mut rng, d * kvd, inv_sqrt_d),
                wo: scaled(&mut rng, qd * d, 0.5 / (qd as f32).sqrt()),
                n1: vec![1.0f32; d],
                n2: vec![1.0f32; d],
                router,
                wg: scaled(&mut rng, n * d * h, inv_sqrt_d),
                wu: scaled(&mut rng, n * d * h, inv_sqrt_d),
                wd: scaled(&mut rng, n * h * d, 0.5 / (h as f32).sqrt()),
            });
        }

        if opts.residency.is_some() && opts.dispatch == DispatchMode::Gather {
            // loud failure, like the env-var typo path: gather mode runs
            // whole-batch GEMMs out of the raw weights and never consults
            // panels, so a "cached" gather run would silently measure
            // nothing
            panic!("expert residency requires grouped dispatch (OEA_DISPATCH=grouped)");
        }
        let ep_ranks = opts.ep_ranks;
        if ep_ranks == 0 || ep_ranks > n {
            panic!("ep_ranks={ep_ranks} must be in 1..={n} (n_experts)");
        }
        if ep_ranks > 1 && opts.dispatch == DispatchMode::Gather {
            // same rationale: the gather oracle runs whole-batch GEMMs out
            // of the raw weights — there is no per-rank work list to shard
            panic!("expert-parallel sharding requires grouped dispatch (OEA_DISPATCH=grouped)");
        }
        if opts.panel_dtype != PanelDtype::F32 && opts.dispatch == DispatchMode::Gather {
            // the gather oracle executes the raw f32 weights directly and
            // never consults packed panels, so a "quantized" gather run
            // would silently measure full precision
            panic!("quantized panels require grouped dispatch (OEA_DISPATCH=grouped)");
        }
        let packed = match (opts.dispatch, opts.residency) {
            // residency: panels page in lazily on first touch, so nothing
            // is packed up front (the cold-start memory win)
            (DispatchMode::Grouped, Some(_)) => Vec::new(),
            // one contiguous panel shard per EP rank (a single whole-layer
            // shard at ep_ranks = 1 — the exact pre-EP pack)
            (DispatchMode::Grouped, None) => layers
                .iter()
                .map(|lw| {
                    (0..ep_ranks)
                        .map(|r| {
                            let (e0, e1) = rank_span(r, n, ep_ranks);
                            let ne = e1 - e0;
                            let dt = opts.panel_dtype;
                            PackedShard {
                                e0,
                                wg: PackedMat::pack_dtype(
                                    &lw.wg[e0 * d * h..e1 * d * h],
                                    ne,
                                    d,
                                    h,
                                    dt,
                                ),
                                wu: PackedMat::pack_dtype(
                                    &lw.wu[e0 * d * h..e1 * d * h],
                                    ne,
                                    d,
                                    h,
                                    dt,
                                ),
                                wd: PackedMat::pack_dtype(
                                    &lw.wd[e0 * h * d..e1 * h * d],
                                    ne,
                                    h,
                                    d,
                                    dt,
                                ),
                            }
                        })
                        .collect()
                })
                .collect(),
            (DispatchMode::Gather, _) => Vec::new(),
        };
        let residency = opts.residency.map(|rc| {
            Mutex::new(
                (0..cfg.n_layers)
                    .map(|_| LayerResidency::new(n, &rc, ep_ranks))
                    .collect(),
            )
        });

        let workers = match opts.threads {
            0 => std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            t => t,
        };
        let pool = if workers > 1 { Some(ThreadPool::new(workers)) } else { None };
        // pinned worker subsets for real rank concurrency: workers split
        // evenly across ranks (min 1 each), so the MoE stage never runs
        // on more threads than the single-pool path would have used
        let rank_pools: Vec<ThreadPool> =
            if opts.dispatch == DispatchMode::Grouped && ep_ranks > 1 && workers > 1 {
                let per_rank = (workers / ep_ranks).max(1);
                (0..ep_ranks).map(|_| ThreadPool::new(per_rank)).collect()
            } else {
                Vec::new()
            };

        CpuBackend {
            expert_load: Mutex::new(vec![0u64; n]),
            cfg,
            embed_w,
            unembed_w,
            final_norm,
            layers,
            packed,
            residency,
            res_cfg: opts.residency,
            ep_ranks,
            mode: opts.dispatch,
            kernels_mode: opts.kernels,
            panel_dtype: opts.panel_dtype,
            pool,
            rank_pools,
            rank_wall: Mutex::new(Vec::new()),
            scratch: ScratchPool::new(),
            faults: None,
            tracer: None,
        }
    }

    /// Install a deterministic fault-injection plan (`--faults`). Like
    /// residency, the plane hooks grouped dispatch only — the gather
    /// oracle runs whole-batch GEMMs with no page-in or per-rank work
    /// list to fail, so a "chaos" gather run would silently inject
    /// nothing. An empty plan installs nothing at all, keeping the
    /// no-faults path bitwise-identical (property-tested).
    pub fn install_faults(&mut self, plan: FaultPlan) {
        if plan.is_empty() {
            return;
        }
        if self.mode == DispatchMode::Gather {
            panic!("fault injection requires grouped dispatch (OEA_DISPATCH=grouped)");
        }
        self.faults = Some(Mutex::new(FaultState::new(
            plan,
            self.cfg.n_layers,
            self.cfg.n_experts,
            self.ep_ranks,
        )));
        // a tracer installed first still sees fault-ledger instants
        if let (Some(fs), Some(tr)) = (&self.faults, &self.tracer) {
            lock_clean(fs).set_tracer(Some(Arc::clone(tr)));
        }
    }

    /// Attach the flight recorder (`--trace`): residency page-in and
    /// prefetch instants land on the backend track, and fault-ledger
    /// pushes mirror onto the event track. Like [`install_faults`], not
    /// installing one keeps every hot path free of tracing code.
    ///
    /// [`install_faults`]: CpuBackend::install_faults
    pub fn install_tracer(&mut self, tracer: Arc<Tracer>) {
        if let Some(fs) = &self.faults {
            lock_clean(fs).set_tracer(Some(Arc::clone(&tracer)));
        }
        self.tracer = Some(tracer);
    }

    pub fn dispatch_mode(&self) -> DispatchMode {
        self.mode
    }

    /// Kernel implementation the hot paths were constructed with.
    pub fn kernel_mode(&self) -> KernelMode {
        self.kernels_mode
    }

    /// Storage dtype the expert panels were packed in.
    pub fn panel_dtype(&self) -> PanelDtype {
        self.panel_dtype
    }

    /// Snapshot of cumulative per-expert routed-token counts.
    pub fn expert_loads(&self) -> Vec<u64> {
        self.expert_load.lock().unwrap().clone()
    }

    pub fn reset_expert_loads(&self) {
        for x in self.expert_load.lock().unwrap().iter_mut() {
            *x = 0;
        }
    }

    /// Zero the residency ledger without touching residency *state*
    /// (what's loaded stays loaded) — benches reset after warmup so hit
    /// rates reflect steady-state behaviour, not compulsory cold misses.
    pub fn reset_residency_counters(&self) {
        if let Some(res) = &self.residency {
            for lr in res.lock().unwrap().iter_mut() {
                for rr in lr.ranks.iter_mut() {
                    rr.counters = ResidencyCounters::default();
                }
            }
        }
    }

    /// Fresh-allocation count of the backend's shared scratch pool
    /// (stable across steps once warm; per-thread kernel arenas are
    /// tracked separately via `util::arena::thread_arena_fresh_allocs`).
    pub fn scratch_fresh_allocs(&self) -> u64 {
        self.scratch.fresh_allocs()
    }

    /// `S * Hkv * hd` — one slot's cache row length.
    fn row_len(&self) -> usize {
        self.cfg.s_max * self.cfg.n_kv_heads * self.cfg.head_dim
    }

    /// Residency: apply the lookahead predictions recorded at the
    /// PREVIOUS step (see residency::prefetch) before this step's routing
    /// decision and expert execution — the paged-in experts are resident
    /// by the time routing and dispatch look. Shared by the decode path
    /// (`layer_pre`) and chunked prefill; paging order never changes
    /// panel bytes, so applying the wave per chunk instead of per token
    /// cannot change any output.
    fn apply_prefetch_wave(&self, l: usize) {
        let Some(res) = &self.residency else { return };
        let c = &self.cfg;
        let lw = &self.layers[l];
        let (d, h) = (c.d_model, c.d_expert);
        let mut res = res.lock().unwrap();
        let lr = &mut res[l];
        // each rank applies its own prediction wave within its shard
        for rr in lr.ranks.iter_mut() {
            let pending = rr.prefetch.take_pending();
            // wave protection: this step's predictions must not evict
            // each other (admits are recency-silent, so wave-mates
            // would otherwise be each other's "stalest" victims)
            let mut wave: Vec<usize> = Vec::with_capacity(pending.len());
            for le in pending {
                let le = le as usize;
                if let Some(evicted) = rr.set.admit_protecting(le, &wave) {
                    if let Some(v) = evicted {
                        rr.drop_panel(v);
                    }
                    rr.counters.prefetches += 1;
                    if let Some(tr) = &self.tracer {
                        tr.instant(
                            "prefetch",
                            BACKEND_TID,
                            vec![
                                ("layer", Json::num(l as f64)),
                                ("expert", Json::num((rr.e0 + le) as f64)),
                            ],
                        );
                    }
                    rr.page_in(lw, le, d, h, self.panel_dtype);
                    wave.push(le);
                }
            }
        }
    }

    /// Decode attention over the updated cache, expert rows fanned out
    /// over the pool (per-row math is chunk-invariant, so any split is
    /// bitwise-identical to serial).
    fn attention(&self, q: &[f32], kc: &[f32], vc: &[f32], b: usize, pos: &[i32], out: &mut [f32]) {
        let c = &self.cfg;
        let (hq, hkv, hd) = (c.n_q_heads, c.n_kv_heads, c.head_dim);
        let s_max = c.s_max;
        let row = hq * hd;
        let workers = self.pool.as_ref().map(|p| p.size()).unwrap_or(1);
        let nchunks = workers.min(b).max(1);
        if nchunks <= 1 {
            with_thread_arena(|arena| {
                let mut logits = arena.take(s_max);
                kernels::decode_attention_rows(
                    q, kc, vc, s_max, hq, hkv, hd, pos, 0, out, &mut logits,
                );
                arena.put(logits);
            });
            return;
        }
        let rows_per = b.div_ceil(nchunks);
        let items: Vec<(usize, &mut [f32])> = out
            .chunks_mut(rows_per * row)
            .enumerate()
            .map(|(ci, chunk)| (ci * rows_per, chunk))
            .collect();
        self.pool.as_ref().unwrap().scoped_map(items, |(start, chunk): (usize, &mut [f32])| {
            with_thread_arena(|arena| {
                let mut logits = arena.take(s_max);
                kernels::decode_attention_rows(
                    q, kc, vc, s_max, hq, hkv, hd, pos, start, chunk, &mut logits,
                );
                arena.put(logits);
            });
        });
    }

    /// Grouped-dispatch expert FFN + residual: `hidden + Σ_groups ...`.
    fn moe_apply_grouped(
        &self,
        l: usize,
        hidden: &[f32],
        groups: &ExpertGroups,
    ) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let (d, n) = (c.d_model, c.n_experts);
        let b = hidden.len() / d;
        if groups.b != b || groups.n_experts != n {
            return Err(Error::Engine(format!(
                "moe groups shape [{}x{}] != batch [{}x{}]",
                groups.b, groups.n_experts, b, n
            )));
        }
        for grp in groups.iter() {
            if grp.expert >= n {
                return Err(Error::Engine(format!(
                    "moe group expert {} out of range",
                    grp.expert
                )));
            }
        }
        if groups.ranks > 1 && groups.ranks != self.ep_ranks {
            // a routing decision sharded for R ranks executing on a
            // backend sharded differently would silently mis-attribute
            // every per-rank number — fail loudly instead
            return Err(Error::Engine(format!(
                "routing decision sharded for {} ranks on a backend with ep_ranks={}",
                groups.ranks, self.ep_ranks
            )));
        }
        // Fault plane (one lock, before any other is held): layer 0 marks
        // a new forward pass (the step clock every after_steps clause
        // counts), the one-shot step panic fires while NO lock is held so
        // the engine's catch_unwind recovery never meets a poisoned mutex,
        // and the per-rank stall schedule + this layer's poison targets
        // are snapshotted so the parallel section below never touches the
        // fault mutex.
        let mut stall_us: Vec<u64> = Vec::new();
        let mut poison: Vec<usize> = Vec::new();
        if let Some(fs) = &self.faults {
            let mut st = lock_clean(fs);
            if l == 0 {
                st.begin_forward_pass();
            }
            let fire = st.should_panic(l);
            stall_us = (0..self.ep_ranks).map(|r| st.stall_us(r)).collect();
            poison = st.poison_targets(l);
            drop(st);
            if fire {
                panic!("injected fault: step-panic at layer {l}");
            }
        }
        let lw = &self.layers[l];
        let h = c.d_expert;
        // Residency bookkeeping first, under one lock: touch every
        // group's expert (ascending order — the access trace the eviction
        // policies see) in its OWN RANK's residency set, page misses in by
        // lazily packing their panels (the simulated page-in cost is that
        // real packing work), and collect panel handles so a later group's
        // eviction cannot pull weights out from under this step's
        // execution. Per-rank sets partition the expert axis, so at
        // ep_ranks = 1 this is exactly the old single-set trace.
        let mut fault_sleep_us: u64 = 0;
        let panels: Option<Vec<Arc<ExpertPanels>>> = self.residency.as_ref().map(|res| {
            let mut res = lock_clean(res);
            let lr = &mut res[l];
            groups
                .iter()
                .map(|grp| {
                    let e = grp.expert;
                    let rr = &mut lr.ranks[rank_of(e, n, self.ep_ranks)];
                    let le = e - rr.e0;
                    match rr.set.touch(le) {
                        Touch::Hit => rr.counters.hits += 1,
                        Touch::Miss { evicted } => {
                            rr.counters.misses += 1;
                            if let Some(v) = evicted {
                                rr.drop_panel(v);
                            }
                            // injected page-in failures/delays: the fault
                            // state plans the whole retry schedule in one
                            // lock (trips health on an exhausted budget);
                            // the sleeps run AFTER both locks drop, and
                            // the final page-in always succeeds — weights
                            // are local, so a flaky transport degrades
                            // routing but can never wedge execution
                            if let Some(fs) = &self.faults {
                                let out = lock_clean(fs).pagein_plan(l, e);
                                fault_sleep_us += out.delay_us;
                                fault_sleep_us += out.backoff_us.iter().sum::<u64>();
                            }
                            if let Some(tr) = &self.tracer {
                                tr.instant(
                                    "page_in",
                                    BACKEND_TID,
                                    vec![
                                        ("layer", Json::num(l as f64)),
                                        ("expert", Json::num(e as f64)),
                                        ("evicted", Json::Bool(evicted.is_some())),
                                    ],
                                );
                            }
                            rr.page_in(lw, le, d, h, self.panel_dtype);
                        }
                    }
                    Arc::clone(rr.panels[le].as_ref().expect("resident expert has panels"))
                })
                .collect()
        });
        if fault_sleep_us > 0 {
            std::thread::sleep(Duration::from_micros(fault_sleep_us));
        }
        let shards = if panels.is_none() { Some(&self.packed[l]) } else { None };
        let kmode = self.kernels_mode;
        let mut hn = self.scratch.take(b * d);
        kernels::rmsnorm_into_mode(hidden, &lw.n2, d, c.rms_eps, &mut hn, kmode);
        let mut acc = self.scratch.take(b * d);
        let ngroups = groups.len();
        let workers = self.pool.as_ref().map(|p| p.size()).unwrap_or(1);
        // per-rank work lists: each chunk of groups belongs to exactly one
        // rank (and therefore one panel shard)
        let ranges = groups.rank_ranges(self.ep_ranks);
        // One executor for both panel sources: residency panels hold the
        // same packed bytes as the shard pack, and both run through
        // kernels::moe_ffn_group_rows, so outputs are bitwise-identical
        // with or without residency bookkeeping.
        let hn_ref = &hn;
        let stall_ref = &stall_us;
        let run_range = |rank: usize, g0: usize, g1: usize, out: &mut [f32], arena: &mut Arena| {
            // injected rank stall: charged once per layer execution, on
            // the rank's FIRST chunk (so worker-count splits don't
            // multiply the stall), delaying exactly the work that rank
            // owns — the EP max-rank latency driver the paper's §7 cost
            // model keys on
            if g1 > g0 && g0 == ranges[rank].0 {
                if let Some(&us) = stall_ref.get(rank) {
                    if us > 0 {
                        std::thread::sleep(Duration::from_micros(us));
                    }
                }
            }
            match (&panels, shards) {
                (Some(ps), _) => {
                    for gi in g0..g1 {
                        let grp = groups.group(gi);
                        let p = &ps[gi];
                        kernels::moe_ffn_group_rows(
                            hn_ref,
                            p.wg.expert_view(0),
                            p.wu.expert_view(0),
                            p.wd.expert_view(0),
                            d,
                            h,
                            p.wg.n_pad,
                            p.wd.n_pad,
                            grp.rows,
                            grp.weights,
                            out,
                            arena,
                            kmode,
                        );
                    }
                }
                (None, Some(shards)) => {
                    let pk = &shards[rank];
                    kernels::moe_ffn_groups(
                        hn_ref, &pk.wg, &pk.wu, &pk.wd, pk.e0, groups, g0, g1, out, arena, kmode,
                    )
                }
                (None, None) => unreachable!("no packed panels and no residency"),
            }
        };
        let mut rank_wall = vec![0.0f64; self.ep_ranks];
        if workers <= 1 || ngroups <= 1 {
            with_thread_arena(|arena| {
                for (rank, &(g0, g1)) in ranges.iter().enumerate() {
                    let t0 = std::time::Instant::now();
                    run_range(rank, g0, g1, &mut acc, arena);
                    rank_wall[rank] = t0.elapsed().as_secs_f64() * 1e6;
                }
            });
        } else if !self.rank_pools.is_empty() {
            // Real rank concurrency: one driver thread per active rank
            // executes that rank's chunk list on its own pinned worker
            // subset while the driver clocks the rank's wall time — the
            // measured counterpart of the cost model's analytic
            // max-over-ranks step cost. Partials still reduce in (rank
            // ascending, chunk ascending) order below, exactly the
            // serial ascending-expert order, so concurrent execution
            // never changes the reduction order.
            let scratch = &self.scratch;
            let run_range = &run_range;
            let rank_parts: Vec<(usize, f64, Vec<Vec<f32>>)> = std::thread::scope(|s| {
                let handles: Vec<_> = ranges
                    .iter()
                    .enumerate()
                    .filter(|&(_, &(g0, g1))| g1 > g0)
                    .map(|(rank, &(g0, g1))| {
                        let rpool = &self.rank_pools[rank];
                        s.spawn(move || {
                            let t0 = std::time::Instant::now();
                            let mut chunks = Vec::new();
                            chunk_rank(groups, rpool.size(), rank, g0, g1, &mut chunks);
                            let parts = if rpool.size() > 1 && chunks.len() > 1 {
                                rpool.scoped_map(
                                    chunks,
                                    |(r, c0, c1): (usize, usize, usize)| {
                                        let mut part = scratch.take(b * d);
                                        with_thread_arena(|arena| {
                                            run_range(r, c0, c1, &mut part, arena)
                                        });
                                        part
                                    },
                                )
                            } else {
                                let mut part = scratch.take(b * d);
                                with_thread_arena(|arena| {
                                    run_range(rank, g0, g1, &mut part, arena)
                                });
                                vec![part]
                            };
                            (rank, t0.elapsed().as_secs_f64() * 1e6, parts)
                        })
                    })
                    .collect();
                // spawn order is rank-ascending; joining in that order
                // keeps the reduction deterministic
                handles
                    .into_iter()
                    .map(|hd| match hd.join() {
                        Ok(v) => v,
                        Err(e) => std::panic::resume_unwind(e),
                    })
                    .collect()
            });
            for (rank, wall, parts) in rank_parts {
                rank_wall[rank] = wall;
                for part in parts {
                    for (o, &pv) in acc.iter_mut().zip(part.iter()) {
                        *o += pv;
                    }
                    self.scratch.put(part);
                }
            }
        } else {
            let t0 = std::time::Instant::now();
            let chunks = chunk_groups(groups, workers, &ranges);
            let scratch = &self.scratch;
            let pool = self.pool.as_ref().unwrap();
            let partials = pool.scoped_map(chunks, |(rank, g0, g1): (usize, usize, usize)| {
                let mut part = scratch.take(b * d);
                with_thread_arena(|arena| run_range(rank, g0, g1, &mut part, arena));
                part
            });
            // reduce in chunk order == ascending-expert order (see
            // chunk_groups). Deterministic for a fixed worker count; a
            // token whose 3+ experts straddle a chunk boundary sums with
            // different float parenthesization than serial, so across
            // thread counts agreement is to rounding (~ulp), not bitwise.
            for part in partials {
                for (o, &pv) in acc.iter_mut().zip(part.iter()) {
                    *o += pv;
                }
                self.scratch.put(part);
            }
            // single-rank pooled path: the whole MoE stage is rank 0's wall
            rank_wall[0] = t0.elapsed().as_secs_f64() * 1e6;
        }
        *lock_clean(&self.rank_wall) = rank_wall;
        {
            let mut load = lock_clean(&self.expert_load);
            for grp in groups.iter() {
                load[grp.expert] += grp.rows.len() as u64;
            }
        }
        let mut out = hidden.to_vec();
        for (o, &yv) in out.iter_mut().zip(acc.iter()) {
            *o += yv;
        }
        // injected expert poisoning: overwrite the poisoned expert's
        // routed rows with NaN — exactly what a corrupted FFN panel would
        // produce post-residual. Detection (first NaN emission trips the
        // expert unhealthy) happens here, outside the parallel section;
        // the NaN still flows to this step's logits, where the engine's
        // non-finite guard retires the affected request, and from the
        // NEXT step on the tripped expert is health-masked out of routing.
        if !poison.is_empty() {
            for grp in groups.iter() {
                if poison.contains(&grp.expert) {
                    for &row in grp.rows {
                        let r = row as usize;
                        out[r * d..(r + 1) * d].fill(f32::NAN);
                    }
                    if let Some(fs) = &self.faults {
                        lock_clean(fs).note_poisoned(l, grp.expert, grp.rows.len() as u64);
                    }
                }
            }
        }
        // probation re-admission: every group that executed this layer
        // without being poisoned counts as a clean trial for a half-open
        // expert (an exhausted page-in budget already re-tripped it in
        // pagein_plan above, clearing half-open, so it no-ops here). The
        // has_half_open() fast check keeps the common no-probation path
        // at one lock acquisition and zero per-group work.
        if let Some(fs) = &self.faults {
            let mut st = lock_clean(fs);
            if st.has_half_open() {
                for grp in groups.iter() {
                    if !poison.contains(&grp.expert) {
                        st.note_probation_success(l, grp.expert);
                    }
                }
            }
        }
        self.scratch.put(acc);
        self.scratch.put(hn);
        Ok(out)
    }
}

impl Backend for CpuBackend {
    type Cache = CpuKvCache;
    type Rows = CpuKvRows;

    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn label(&self) -> &'static str {
        "cpu"
    }

    fn new_cache(&self, bucket: usize) -> Result<CpuKvCache> {
        let layers = (0..self.cfg.n_layers)
            .map(|_| vec![0.0f32; 2 * bucket * self.row_len()])
            .collect();
        Ok(CpuKvCache { bucket, layers })
    }

    fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (d, v) = (self.cfg.d_model, self.cfg.vocab);
        let mut out = vec![0.0f32; tokens.len() * d];
        for (i, &t) in tokens.iter().enumerate() {
            // clamp like jnp.take's default out-of-bounds behaviour
            let t = (t.max(0) as usize).min(v - 1);
            out[i * d..(i + 1) * d].copy_from_slice(&self.embed_w[t * d..(t + 1) * d]);
        }
        Ok(out)
    }

    fn layer_pre(
        &self,
        l: usize,
        hidden: &[f32],
        cache: &mut CpuKvCache,
        pos: &[i32],
    ) -> Result<LayerPre> {
        let c = &self.cfg;
        let b = pos.len();
        if hidden.len() != b * c.d_model || cache.bucket != b {
            return Err(Error::Engine(format!(
                "layer_pre shape mismatch: hidden {} pos {} bucket {}",
                hidden.len(),
                b,
                cache.bucket
            )));
        }
        self.apply_prefetch_wave(l);
        let lw = &self.layers[l];
        let (d, qd, kvd) = (c.d_model, c.q_dim(), c.kv_dim());
        let (hq, hkv, hd) = (c.n_q_heads, c.n_kv_heads, c.head_dim);

        let mut h1 = self.scratch.take(b * d);
        kernels::rmsnorm_into_mode(hidden, &lw.n1, d, c.rms_eps, &mut h1, self.kernels_mode);
        let mut q = self.scratch.take(b * qd);
        let mut k = self.scratch.take(b * kvd);
        let mut v = self.scratch.take(b * kvd);
        kernels::matmul_into(&h1, &lw.wq, b, d, qd, &mut q);
        kernels::matmul_into(&h1, &lw.wk, b, d, kvd, &mut k);
        kernels::matmul_into(&h1, &lw.wv, b, d, kvd, &mut v);
        self.scratch.put(h1);
        kernels::rope(&mut q, hq, hd, pos, c.rope_theta);
        kernels::rope(&mut k, hkv, hd, pos, c.rope_theta);

        // slot-stable cache append: row b's slot pos[b] gets this step's K/V
        let row = self.row_len();
        let half = b * row;
        let cl = &mut cache.layers[l];
        for i in 0..b {
            let slot = (pos[i].max(0) as usize).min(c.s_max - 1);
            let dst = i * row + slot * kvd;
            cl[dst..dst + kvd].copy_from_slice(&k[i * kvd..(i + 1) * kvd]);
            cl[half + dst..half + dst + kvd].copy_from_slice(&v[i * kvd..(i + 1) * kvd]);
        }
        self.scratch.put(k);
        self.scratch.put(v);

        // attention over the UPDATED cache (model.py layer_pre semantics),
        // batch rows fanned out over the pool
        let (kc, vc) = cl.split_at(half);
        let mut attn = self.scratch.take(b * qd);
        self.attention(&q, kc, vc, b, pos, &mut attn);
        self.scratch.put(q);
        let mut ao = self.scratch.take(b * d);
        kernels::matmul_into(&attn, &lw.wo, b, qd, d, &mut ao);
        self.scratch.put(attn);
        let mut h_out = hidden.to_vec();
        for (o, &a) in h_out.iter_mut().zip(ao.iter()) {
            *o += a;
        }
        self.scratch.put(ao);
        // router scores with pooled norm scratch (the score Vec itself
        // escapes into LayerPre, so it cannot come from the pool)
        let mut rhn = self.scratch.take(b * d);
        let mut scores = vec![0.0f32; b * c.n_experts];
        kernels::router_scores_into(
            &h_out,
            &lw.n2,
            &lw.router,
            b,
            d,
            c.n_experts,
            c.rms_eps,
            &mut rhn,
            &mut scores,
            self.kernels_mode,
        );
        self.scratch.put(rhn);
        Ok(LayerPre { h: h_out, scores })
    }

    fn moe_apply(
        &self,
        l: usize,
        hidden: &[f32],
        combine: &[f32],
        ids: &[i32],
    ) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let (d, h, n) = (c.d_model, c.d_expert, c.n_experts);
        let b = hidden.len() / d;
        if combine.len() != b * n {
            return Err(Error::Engine(format!(
                "moe_apply combine len {} != {}x{}",
                combine.len(),
                b,
                n
            )));
        }
        for &id in ids {
            if id < 0 || id as usize >= n {
                return Err(Error::Engine(format!("moe_apply expert id {id} out of range")));
            }
        }
        match self.mode {
            DispatchMode::Grouped => {
                let groups = ExpertGroups::from_combine(combine, ids, b, n);
                self.moe_apply_grouped(l, hidden, &groups)
            }
            DispatchMode::Gather => {
                let lw = &self.layers[l];
                let mut hn = self.scratch.take(b * d);
                kernels::rmsnorm_into(hidden, &lw.n2, d, c.rms_eps, &mut hn);
                let mut y = self.scratch.take(b * d);
                with_thread_arena(|arena| {
                    kernels::moe_ffn_gather_into(
                        &hn, &lw.wg, &lw.wu, &lw.wd, combine, ids, b, d, h, n, &mut y, arena,
                    );
                });
                {
                    // telemetry: routed (nonzero-combine) tokens of the
                    // experts the kernel actually executed (those in
                    // `ids`), so the histogram matches grouped dispatch
                    // on identical inputs
                    let mut active = vec![false; n];
                    for &id in ids {
                        active[id as usize] = true;
                    }
                    let mut load = self.expert_load.lock().unwrap();
                    for rowc in combine.chunks_exact(n) {
                        for (e, &cv) in rowc.iter().enumerate() {
                            if active[e] && cv != 0.0 {
                                load[e] += 1;
                            }
                        }
                    }
                }
                let mut out = hidden.to_vec();
                for (o, &yv) in out.iter_mut().zip(y.iter()) {
                    *o += yv;
                }
                self.scratch.put(y);
                self.scratch.put(hn);
                Ok(out)
            }
        }
    }

    fn moe_apply_routed(&self, l: usize, hidden: &[f32], step: &RoutedStep) -> Result<Vec<f32>> {
        match self.mode {
            // the serving path: groups come straight from the routing
            // decision, no dense combine scan needed
            DispatchMode::Grouped => self.moe_apply_grouped(l, hidden, step.groups),
            DispatchMode::Gather => self.moe_apply(l, hidden, step.combine, step.ids),
        }
    }

    /// Final-norm + unembedding GEMM `[B, D] x [D, V]` — the largest
    /// single GEMM of a decode step. Batch rows fan out over the pool in
    /// micro-kernel-aligned chunks; per-row accumulation order is
    /// identical under any row split, so the parallel result is the same
    /// as serial (see `logits_parallel_matches_serial` in
    /// `tests/dispatch_equivalence.rs`).
    fn logits(&self, hidden: &[f32]) -> Result<Vec<f32>> {
        let (d, v) = (self.cfg.d_model, self.cfg.vocab);
        let b = hidden.len() / d;
        let mut hn = self.scratch.take(b * d);
        kernels::rmsnorm_into_mode(
            hidden,
            &self.final_norm,
            d,
            self.cfg.rms_eps,
            &mut hn,
            self.kernels_mode,
        );
        let mut out = vec![0.0f32; b * v];
        let workers = self.pool.as_ref().map(|p| p.size()).unwrap_or(1);
        if workers <= 1 || b <= 4 {
            kernels::matmul_into(&hn, &self.unembed_w, b, d, v, &mut out);
        } else {
            // rows per chunk: even split across workers, rounded up to the
            // GEMM micro-kernel's 4-row pass so no chunk wastes a pass
            let rows_per = b.div_ceil(workers).div_ceil(4) * 4;
            let items: Vec<(&[f32], &mut [f32])> = hn
                .chunks(rows_per * d)
                .zip(out.chunks_mut(rows_per * v))
                .collect();
            let w = &self.unembed_w;
            self.pool.as_ref().unwrap().scoped_map(items, |(a, o): (&[f32], &mut [f32])| {
                kernels::matmul_into(a, w, o.len() / v, d, v, o);
            });
        }
        self.scratch.put(hn);
        Ok(out)
    }

    /// Teacher-forced prefill: the prompt runs through the decode path one
    /// token at a time with in-graph vanilla routing, which is *exactly*
    /// the decode pipeline's math — prefill/decode consistency holds by
    /// construction (the chunked-prefill fast path is a PJRT artifact
    /// concern; the reference backend favours exactness).
    fn prefill(&self, prompt: &[i32]) -> Result<Prefilled<CpuKvRows>> {
        let c = self.cfg.clone();
        if prompt.is_empty() {
            return Err(Error::Engine("empty prompt".into()));
        }
        if prompt.len() > c.s_max - 1 {
            return Err(Error::Engine(format!(
                "prompt of {} tokens exceeds s_max-1 = {}",
                prompt.len(),
                c.s_max - 1
            )));
        }
        let mut cache = self.new_cache(1)?;
        let mut last_hidden = Vec::new();
        // prefill routes vanilla per token (paper: OEA is decode-only)
        // but still runs through the shared expert cache — its touches
        // count in the residency ledger, since serving a prompt really
        // does page those weights in (see README's scoping note)
        for (t, &tok) in prompt.iter().enumerate() {
            let mut hidden = self.embed(&[tok])?;
            for l in 0..c.n_layers {
                let pre = self.layer_pre(l, &hidden, &mut cache, &[t as i32])?;
                let scores = ScoreMatrix::new(1, c.n_experts, pre.scores);
                let live = [true];
                // prefill honors the health mask too: a prompt routed
                // through a poisoned expert would NaN its whole KV trail
                let healthy = self.faults.as_ref().and_then(|fs| lock_clean(fs).healthy_for(l));
                let d = policy::route(
                    Policy::Vanilla { k: c.top_k },
                    &RoutingInput {
                        scores: &scores,
                        live: &live,
                        mask_padding: true,
                        resident: None,
                        healthy: healthy.as_deref(),
                    },
                );
                let ids: Vec<i32> = d.active.iter().map(|&e| e as i32).collect();
                hidden = self.moe_apply(l, &pre.h, &d.combine, &ids)?;
            }
            last_hidden = hidden;
        }
        let last_logits = self.logits(&last_hidden)?;
        let row = self.row_len();
        let mut k_rows = Vec::with_capacity(c.n_layers);
        let mut v_rows = Vec::with_capacity(c.n_layers);
        for cl in &cache.layers {
            k_rows.push(cl[..row].to_vec());
            v_rows.push(cl[row..2 * row].to_vec());
        }
        Ok(Prefilled {
            rows: CpuKvRows { k: k_rows, v: v_rows },
            n_tokens: prompt.len(),
            last_logits,
        })
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    /// Chunked prefill straight into the decode cache: the whole chunk
    /// runs each stage as ONE batched pass (`m = chunk` GEMMs instead of
    /// `chunk` sequential `m = 1` passes — the continuous scheduler's
    /// prefill win), with causal attention over the slot's cache prefix.
    /// Every kernel accumulates per output row in the same order at any
    /// `m`, so each row's result is bitwise-identical to the
    /// token-by-token [`Backend::prefill`] path (the lockstep oracle).
    fn prefill_chunk(
        &self,
        cache: &mut CpuKvCache,
        slot: usize,
        tokens: &[i32],
        pos0: usize,
    ) -> Result<Vec<f32>> {
        let c = self.cfg.clone();
        let b = cache.bucket;
        let cn = tokens.len();
        if slot >= b {
            return Err(Error::Engine(format!("slot {slot} out of bucket {b}")));
        }
        if cn == 0 {
            return Err(Error::Engine("empty prefill chunk".into()));
        }
        if pos0 + cn > c.s_max - 1 {
            return Err(Error::Engine(format!(
                "prefill chunk [{pos0}, {}) exceeds s_max-1 = {}",
                pos0 + cn,
                c.s_max - 1
            )));
        }
        let (d, qd, kvd) = (c.d_model, c.q_dim(), c.kv_dim());
        let (hq, hkv, hd) = (c.n_q_heads, c.n_kv_heads, c.head_dim);
        let pos: Vec<i32> = (0..cn).map(|j| (pos0 + j) as i32).collect();
        let row = self.row_len();
        let half = b * row;

        let mut hidden = self.embed(tokens)?;
        // a prefill chunk has no padding rows — every row routes
        let live = vec![true; cn];
        for l in 0..c.n_layers {
            self.apply_prefetch_wave(l);
            let lw = &self.layers[l];
            let mut h1 = self.scratch.take(cn * d);
            kernels::rmsnorm_into_mode(&hidden, &lw.n1, d, c.rms_eps, &mut h1, self.kernels_mode);
            let mut q = self.scratch.take(cn * qd);
            let mut k = self.scratch.take(cn * kvd);
            let mut v = self.scratch.take(cn * kvd);
            kernels::matmul_into(&h1, &lw.wq, cn, d, qd, &mut q);
            kernels::matmul_into(&h1, &lw.wk, cn, d, kvd, &mut k);
            kernels::matmul_into(&h1, &lw.wv, cn, d, kvd, &mut v);
            self.scratch.put(h1);
            kernels::rope(&mut q, hq, hd, &pos, c.rope_theta);
            kernels::rope(&mut k, hkv, hd, &pos, c.rope_theta);

            // the whole chunk's K/V lands in the slot's cache rows BEFORE
            // attention reads (write-before-read, like the decode path)
            let cl = &mut cache.layers[l];
            for j in 0..cn {
                let dst = slot * row + (pos0 + j) * kvd;
                cl[dst..dst + kvd].copy_from_slice(&k[j * kvd..(j + 1) * kvd]);
                cl[half + dst..half + dst + kvd]
                    .copy_from_slice(&v[j * kvd..(j + 1) * kvd]);
            }
            self.scratch.put(k);
            self.scratch.put(v);

            // causal attention: chunk row j sees the slot prefix 0..=pos0+j
            let (kc, vc) = cl.split_at(half);
            let k_slot = &kc[slot * row..(slot + 1) * row];
            let v_slot = &vc[slot * row..(slot + 1) * row];
            let mut attn = self.scratch.take(cn * qd);
            with_thread_arena(|arena| {
                let mut logits = arena.take(c.s_max);
                kernels::chunk_attention_rows(
                    &q, k_slot, v_slot, c.s_max, hq, hkv, hd, pos0, &mut attn, &mut logits,
                );
                arena.put(logits);
            });
            self.scratch.put(q);
            let mut ao = self.scratch.take(cn * d);
            kernels::matmul_into(&attn, &lw.wo, cn, qd, d, &mut ao);
            self.scratch.put(attn);
            for (o, &a) in hidden.iter_mut().zip(ao.iter()) {
                *o += a;
            }
            self.scratch.put(ao);
            // vanilla routing, like prefill (paper: OEA is decode-only)
            let mut rhn = self.scratch.take(cn * d);
            let mut scores = vec![0.0f32; cn * c.n_experts];
            kernels::router_scores_into(
                &hidden,
                &lw.n2,
                &lw.router,
                cn,
                d,
                c.n_experts,
                c.rms_eps,
                &mut rhn,
                &mut scores,
                self.kernels_mode,
            );
            self.scratch.put(rhn);
            let sm = ScoreMatrix::new(cn, c.n_experts, scores);
            // prefill honors the health mask too: a prompt routed
            // through a poisoned expert would NaN its whole KV trail
            let healthy = self.faults.as_ref().and_then(|fs| lock_clean(fs).healthy_for(l));
            let dec = policy::route(
                Policy::Vanilla { k: c.top_k },
                &RoutingInput {
                    scores: &sm,
                    live: &live,
                    mask_padding: true,
                    resident: None,
                    healthy: healthy.as_deref(),
                },
            );
            let ids: Vec<i32> = dec.active.iter().map(|&e| e as i32).collect();
            hidden = self.moe_apply(l, &hidden, &dec.combine, &ids)?;
        }
        Ok(hidden[(cn - 1) * d..cn * d].to_vec())
    }

    fn install_rows(&self, cache: &mut CpuKvCache, slot: usize, rows: &CpuKvRows) -> Result<()> {
        let row = self.row_len();
        let b = cache.bucket;
        if slot >= b {
            return Err(Error::Engine(format!("slot {slot} out of bucket {b}")));
        }
        for (l, cl) in cache.layers.iter_mut().enumerate() {
            let half = b * row;
            cl[slot * row..(slot + 1) * row].copy_from_slice(&rows.k[l]);
            cl[half + slot * row..half + (slot + 1) * row].copy_from_slice(&rows.v[l]);
        }
        Ok(())
    }

    fn clear_slot(&self, cache: &mut CpuKvCache, slot: usize) -> Result<()> {
        let row = self.row_len();
        let b = cache.bucket;
        if slot >= b {
            return Err(Error::Engine(format!("slot {slot} out of bucket {b}")));
        }
        for cl in cache.layers.iter_mut() {
            let half = b * row;
            cl[slot * row..(slot + 1) * row].fill(0.0);
            cl[half + slot * row..half + (slot + 1) * row].fill(0.0);
        }
        Ok(())
    }

    fn repack(
        &self,
        cache: &CpuKvCache,
        old_bucket: usize,
        new_bucket: usize,
        mapping: &[Option<usize>],
    ) -> Result<CpuKvCache> {
        if cache.bucket != old_bucket || mapping.len() != old_bucket {
            return Err(Error::Engine("repack mapping/bucket mismatch".into()));
        }
        let row = self.row_len();
        let mut out = self.new_cache(new_bucket)?;
        for (l, cl) in cache.layers.iter().enumerate() {
            let fresh = &mut out.layers[l];
            for half in 0..2 {
                let src_base = half * old_bucket * row;
                let dst_base = half * new_bucket * row;
                for (i, m) in mapping.iter().enumerate() {
                    if let Some(j) = m {
                        if *j >= new_bucket {
                            return Err(Error::Engine(format!(
                                "repack target slot {j} out of bucket {new_bucket}"
                            )));
                        }
                        fresh[dst_base + j * row..dst_base + (j + 1) * row]
                            .copy_from_slice(&cl[src_base + i * row..src_base + (i + 1) * row]);
                    }
                }
            }
        }
        Ok(out)
    }

    fn ep_ranks(&self) -> usize {
        self.ep_ranks
    }

    fn expert_loads(&self) -> Option<Vec<u64>> {
        Some(self.expert_load.lock().unwrap().clone())
    }

    fn residency_view(&self, l: usize) -> Option<Vec<bool>> {
        let res = self.residency.as_ref()?;
        let res = res.lock().unwrap();
        let lr = &res[l];
        if lr.ranks.iter().all(|rr| rr.set.unbounded()) {
            // unbounded everywhere: no eviction, so no capacity misses for
            // routing to avoid — the view is withheld and cache-aware ==
            // base OEA (resp. cache-aware EP == plain EP)
            None
        } else {
            // concatenation of the per-rank resident masks: the shards
            // partition the expert axis, so each expert's flag comes from
            // its own rank's set (the rank-local boost)
            let mut mask = vec![false; self.cfg.n_experts];
            for rr in &lr.ranks {
                mask[rr.e0..rr.e0 + rr.panels.len()].copy_from_slice(rr.set.resident_mask());
            }
            Some(mask)
        }
    }

    fn residency_counters(&self, l: usize) -> Option<ResidencyCounters> {
        let res = self.residency.as_ref()?;
        let res = res.lock().unwrap();
        let mut counters = ResidencyCounters::default();
        for rr in &res[l].ranks {
            counters.add(&rr.counters);
        }
        Some(counters)
    }

    fn residency_rank_counters(&self, l: usize) -> Option<Vec<ResidencyCounters>> {
        let res = self.residency.as_ref()?;
        Some(res.lock().unwrap()[l].ranks.iter().map(|rr| rr.counters).collect())
    }

    fn residency_stats(&self) -> Option<ResidencyStats> {
        let res = self.residency.as_ref()?;
        let rc = self.res_cfg.expect("res_cfg present when residency is");
        let res = res.lock().unwrap();
        let mut counters = ResidencyCounters::default();
        let mut resident = 0;
        for lr in res.iter() {
            for rr in &lr.ranks {
                counters.add(&rr.counters);
                resident += rr.set.n_resident();
            }
        }
        // effective per-layer capacity: the rank split rounds up
        // (`ceil(C/R)` per rank, bounded by each shard's size), so the
        // enforceable bound can exceed the configured C when R does not
        // divide it — report what the sets actually hold, keeping
        // `resident <= capacity * layers` true. Reduces to the old
        // `C.clamp(1, n_experts)` at one rank.
        let capacity = res
            .first()
            .map(|lr| {
                lr.ranks
                    .iter()
                    .map(|rr| rr.set.capacity().min(rr.panels.len()))
                    .sum()
            })
            .unwrap_or_else(|| rc.capacity.clamp(1, self.cfg.n_experts));
        Some(ResidencyStats {
            capacity,
            n_experts: self.cfg.n_experts,
            evict: rc.evict,
            prefetch: rc.prefetch,
            counters,
            resident,
            layers: res.len(),
        })
    }

    fn residency_wants_scores(&self) -> bool {
        self.res_cfg
            .is_some_and(|rc| rc.prefetch > 0 || rc.evict == EvictPolicy::ScoreAware)
    }

    fn residency_observe(&self, l: usize, agg: &[f32]) {
        if let Some(res) = &self.residency {
            debug_assert_eq!(agg.len(), self.cfg.n_experts);
            let mut res = res.lock().unwrap();
            let lr = &mut res[l];
            // each rank sees its own shard's slice of the router mass, so
            // score-aware eviction and the prefetcher rank experts
            // rank-locally
            for rr in lr.ranks.iter_mut() {
                let slice = &agg[rr.e0..rr.e0 + rr.panels.len()];
                rr.set.note_scores(slice);
                rr.prefetch.observe(slice);
            }
        }
    }

    fn health_view(&self, l: usize) -> Option<Vec<bool>> {
        let fs = self.faults.as_ref()?;
        lock_clean(fs).healthy_for(l)
    }

    fn note_degraded_tokens(&self, l: usize, degraded: u64, routed: u64) {
        if let Some(fs) = &self.faults {
            lock_clean(fs).note_degraded(l, degraded, routed);
        }
    }

    fn fault_stats(&self) -> Option<FaultStats> {
        let fs = self.faults.as_ref()?;
        Some(lock_clean(fs).stats())
    }

    fn rank_wall_us(&self) -> Vec<f64> {
        lock_clean(&self.rank_wall).clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> CpuBackend {
        CpuBackend::synthetic(ModelConfig::preset("tiny").unwrap(), 0)
    }

    fn backend_with(dispatch: DispatchMode, threads: usize) -> CpuBackend {
        CpuBackend::synthetic_with(
            ModelConfig::preset("tiny").unwrap(),
            0,
            CpuOptions { dispatch, threads, ..CpuOptions::default() },
        )
    }

    #[test]
    fn synthetic_weights_are_deterministic() {
        let a = backend();
        let b = backend();
        assert_eq!(a.embed_w, b.embed_w);
        assert_eq!(a.layers[0].router, b.layers[0].router);
        let c = CpuBackend::synthetic(ModelConfig::preset("tiny").unwrap(), 1);
        assert_ne!(a.embed_w, c.embed_w);
        // dispatch mode never changes the weights
        let g = backend_with(DispatchMode::Gather, 1);
        assert_eq!(a.embed_w, g.embed_w);
        assert_eq!(a.layers[1].wg, g.layers[1].wg);
    }

    #[test]
    fn router_scores_have_realistic_concentration() {
        // top-1 mass dominant but well below 1 — the property the OEA
        // phases interact with (weights.py's stated calibration target)
        let be = backend();
        let c = be.config().clone();
        let mut cache = be.new_cache(4).unwrap();
        let h = be.embed(&[5, 100, 200, 400]).unwrap();
        let pre = be.layer_pre(0, &h, &mut cache, &[0, 0, 0, 0]).unwrap();
        for row in pre.scores.chunks_exact(c.n_experts) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "softmax rows sum to 1, got {sum}");
            let top1 = row.iter().cloned().fold(0.0f32, f32::max);
            assert!(top1 > 1.5 / c.n_experts as f32, "flat router (top1 {top1})");
            assert!(top1 < 0.99, "collapsed router (top1 {top1})");
        }
    }

    #[test]
    fn expert_load_accounting_counts_assignments() {
        for be in [backend_with(DispatchMode::Grouped, 1), backend_with(DispatchMode::Gather, 1)]
        {
            let c = be.config().clone();
            let n = c.n_experts;
            let b = 2;
            let hidden = vec![0.1f32; b * c.d_model];
            let mut combine = vec![0.0f32; b * n];
            combine[0] = 0.6;
            combine[1] = 0.4;
            combine[n + 2] = 1.0;
            be.moe_apply(0, &hidden, &combine, &[0, 1, 2]).unwrap();
            let loads = be.expert_loads();
            assert_eq!(loads[0], 1);
            assert_eq!(loads[1], 1);
            assert_eq!(loads[2], 1);
            assert_eq!(loads.iter().sum::<u64>(), 3);
            be.reset_expert_loads();
            assert_eq!(be.expert_loads().iter().sum::<u64>(), 0);
        }
    }

    #[test]
    fn moe_rejects_out_of_range_ids() {
        for be in [backend_with(DispatchMode::Grouped, 1), backend_with(DispatchMode::Gather, 1)]
        {
            let c = be.config().clone();
            let hidden = vec![0.0f32; c.d_model];
            let combine = vec![0.0f32; c.n_experts];
            assert!(be.moe_apply(0, &hidden, &combine, &[c.n_experts as i32]).is_err());
        }
    }

    #[test]
    fn grouped_matches_gather_per_layer() {
        // one moe_apply under each mode (and threaded vs inline) agrees
        let grouped = backend_with(DispatchMode::Grouped, 1);
        let threaded = backend_with(DispatchMode::Grouped, 3);
        let gather = backend_with(DispatchMode::Gather, 1);
        let c = grouped.config().clone();
        let (b, n) = (4usize, c.n_experts);
        let hidden: Vec<f32> =
            (0..b * c.d_model).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let mut combine = vec![0.0f32; b * n];
        // tokens spread over experts, one token unrouted
        combine[0] = 0.7;
        combine[1] = 0.3;
        combine[n + 1] = 0.5;
        combine[n + 4] = 0.5;
        combine[2 * n + 4] = 1.0;
        let ids = [0i32, 1, 4, 6]; // 6 is active-but-unused padding
        let a = gather.moe_apply(1, &hidden, &combine, &ids).unwrap();
        let g1 = grouped.moe_apply(1, &hidden, &combine, &ids).unwrap();
        let g2 = threaded.moe_apply(1, &hidden, &combine, &ids).unwrap();
        for ((x, y), z) in a.iter().zip(g1.iter()).zip(g2.iter()) {
            assert!((x - y).abs() < 1e-4, "grouped {y} vs gather {x}");
            assert!((y - z).abs() < 1e-6, "threaded {z} vs inline {y}");
        }
        // the unrouted padding row passes through as pure residual
        assert_eq!(&g1[3 * c.d_model..], &hidden[3 * c.d_model..]);
    }

    fn backend_res(capacity: usize, evict: crate::residency::EvictPolicy) -> CpuBackend {
        CpuBackend::synthetic_with(
            ModelConfig::preset("tiny").unwrap(),
            0,
            CpuOptions {
                dispatch: DispatchMode::Grouped,
                threads: 1,
                residency: Some(ResidencyConfig::new(capacity, evict, 0)),
                ..CpuOptions::default()
            },
        )
    }

    /// One-expert-per-token combine row for experts `es` over a 1-row
    /// batch each — drives a deterministic access trace through moe_apply.
    fn touch_experts(be: &CpuBackend, es: &[usize]) {
        let c = be.config().clone();
        let hidden = vec![0.1f32; c.d_model];
        for &e in es {
            let mut combine = vec![0.0f32; c.n_experts];
            combine[e] = 1.0;
            be.moe_apply(0, &hidden, &combine, &[e as i32]).unwrap();
        }
    }

    #[test]
    fn residency_output_bitwise_equals_eager_pack() {
        use crate::residency::EvictPolicy;
        // capacity 2 < groups per call: same-step eviction + repaging
        // must still produce bit-identical output to the eager pack
        let plain = backend_with(DispatchMode::Grouped, 1);
        let res = backend_res(2, EvictPolicy::Lru);
        let c = plain.config().clone();
        let (b, n) = (4usize, c.n_experts);
        let hidden: Vec<f32> =
            (0..b * c.d_model).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let mut combine = vec![0.0f32; b * n];
        combine[0] = 0.7;
        combine[1] = 0.3;
        combine[n + 1] = 0.5;
        combine[n + 4] = 0.5;
        combine[2 * n + 4] = 1.0;
        combine[3 * n + 7] = 1.0;
        let ids = [0i32, 1, 4, 7];
        for l in 0..c.n_layers {
            let a = plain.moe_apply(l, &hidden, &combine, &ids).unwrap();
            let r = res.moe_apply(l, &hidden, &combine, &ids).unwrap();
            assert_eq!(a, r, "layer {l}: residency changed the math");
        }
    }

    #[test]
    fn residency_counts_hits_misses_evictions() {
        use crate::residency::EvictPolicy;
        let be = backend_res(2, EvictPolicy::Lru);
        touch_experts(&be, &[0, 1]); // 2 compulsory misses
        touch_experts(&be, &[0, 1]); // 2 hits
        touch_experts(&be, &[2]); // miss, evicts LRU (expert 0)
        touch_experts(&be, &[0]); // miss again: 0 was evicted
        let s = Backend::residency_stats(&be).unwrap();
        assert_eq!(s.counters.hits, 2);
        assert_eq!(s.counters.misses, 4);
        assert_eq!(s.counters.evictions, 2);
        assert!(s.counters.bytes_paged > 0);
        assert_eq!(s.resident, 2, "layer 0 holds exactly capacity experts");
        assert!((s.counters.hit_rate() - 2.0 / 6.0).abs() < 1e-12);
        be.reset_residency_counters();
        let s2 = Backend::residency_stats(&be).unwrap();
        assert_eq!(s2.counters.accesses(), 0);
        assert_eq!(s2.resident, 2, "reset clears counters, not residency");
    }

    #[test]
    fn residency_pages_lazily_and_view_gates_on_capacity() {
        use crate::residency::EvictPolicy;
        let c = ModelConfig::preset("tiny").unwrap();
        // unbounded capacity: no view (cache-aware == OEA), panels only
        // pack on first touch (cold-start memory drops)
        let be = backend_res(c.n_experts, EvictPolicy::Lru);
        assert!(Backend::residency_view(&be, 0).is_none());
        let s0 = Backend::residency_stats(&be).unwrap();
        assert_eq!(s0.counters.bytes_paged, 0, "nothing packed before first touch");
        touch_experts(&be, &[3]);
        let s1 = Backend::residency_stats(&be).unwrap();
        assert!(s1.counters.bytes_paged > 0);
        touch_experts(&be, &[3]);
        let s2 = Backend::residency_stats(&be).unwrap();
        assert_eq!(s2.counters.bytes_paged, s1.counters.bytes_paged, "hit pages nothing");

        // bounded capacity: the routing view reports exactly the residents
        let bb = backend_res(2, EvictPolicy::Lru);
        touch_experts(&bb, &[5]);
        let view = Backend::residency_view(&bb, 0).unwrap();
        assert!(view[5]);
        assert_eq!(view.iter().filter(|&&r| r).count(), 1);
        // per-layer counters: only layer 0 was touched
        assert_eq!(Backend::residency_counters(&bb, 0).unwrap().misses, 1);
        assert_eq!(Backend::residency_counters(&bb, 1).unwrap().misses, 0);
    }

    #[test]
    fn prefetch_pages_ahead_from_previous_step_scores() {
        use crate::residency::EvictPolicy;
        let c = ModelConfig::preset("tiny").unwrap();
        let be = CpuBackend::synthetic_with(
            c.clone(),
            0,
            CpuOptions {
                dispatch: DispatchMode::Grouped,
                threads: 1,
                residency: Some(ResidencyConfig::new(4, EvictPolicy::Lru, 2)),
                ..CpuOptions::default()
            },
        );
        let mut cache = be.new_cache(2).unwrap();
        let h = be.embed(&[10, 200]).unwrap();
        // step 1: the model runner feeds the batch-aggregated router mass
        // of the ROUTED rows (residency_observe) — recorded as next-step
        // predictions
        let pre = be.layer_pre(0, &h, &mut cache, &[0, 0]).unwrap();
        let n = c.n_experts;
        let mut agg = vec![0.0f32; n];
        for row in pre.scores.chunks_exact(n) {
            for (a, &v) in agg.iter_mut().zip(row.iter()) {
                *a += v;
            }
        }
        Backend::residency_observe(&be, 0, &agg);
        assert_eq!(Backend::residency_stats(&be).unwrap().counters.prefetches, 0);
        // step 2: the pending predictions page in ahead of routing
        be.layer_pre(0, &h, &mut cache, &[1, 1]).unwrap();
        let s = Backend::residency_stats(&be).unwrap();
        assert_eq!(s.counters.prefetches, 2);
        assert!(s.counters.bytes_paged > 0);
        assert_eq!(s.counters.misses, 0, "prefetches are not demand misses");
    }

    #[test]
    #[should_panic(expected = "residency requires grouped dispatch")]
    fn residency_rejects_gather_mode() {
        use crate::residency::EvictPolicy;
        CpuBackend::synthetic_with(
            ModelConfig::preset("tiny").unwrap(),
            0,
            CpuOptions {
                dispatch: DispatchMode::Gather,
                threads: 1,
                residency: Some(ResidencyConfig::new(4, EvictPolicy::Lru, 0)),
                ..CpuOptions::default()
            },
        );
    }

    fn backend_ep(ep_ranks: usize, threads: usize) -> CpuBackend {
        CpuBackend::synthetic_with(
            ModelConfig::preset("tiny").unwrap(),
            0,
            CpuOptions {
                dispatch: DispatchMode::Grouped,
                threads,
                ep_ranks,
                ..CpuOptions::default()
            },
        )
    }

    #[test]
    fn rank_sharded_dispatch_is_bitwise_identical() {
        // each shard's packed rows are byte-identical to the whole-layer
        // pack and groups execute in the same ascending order, so at a
        // fixed worker count every sharding produces bit-identical output
        let base = backend_ep(1, 1);
        let c = base.config().clone();
        let (b, n) = (4usize, c.n_experts);
        let hidden: Vec<f32> =
            (0..b * c.d_model).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let mut combine = vec![0.0f32; b * n];
        combine[0] = 0.7;
        combine[1] = 0.3;
        combine[n + 1] = 0.5;
        combine[n + 4] = 0.5;
        combine[2 * n + 4] = 1.0;
        combine[3 * n + 7] = 1.0;
        let ids = [0i32, 1, 4, 7];
        for l in 0..c.n_layers {
            let want = base.moe_apply(l, &hidden, &combine, &ids).unwrap();
            for ranks in [2usize, 4, 8] {
                let be = backend_ep(ranks, 1);
                let got = be.moe_apply(l, &hidden, &combine, &ids).unwrap();
                assert_eq!(want, got, "layer {l}: ep_ranks={ranks} changed the math");
            }
        }
    }

    #[test]
    fn per_rank_residency_counters_partition_and_balance() {
        use crate::residency::EvictPolicy;
        // ep_ranks=4 over tiny's 8 experts: 2-expert shards, capacity
        // 4 splits to 1 resident per rank
        let be = CpuBackend::synthetic_with(
            ModelConfig::preset("tiny").unwrap(),
            0,
            CpuOptions {
                dispatch: DispatchMode::Grouped,
                threads: 1,
                residency: Some(ResidencyConfig::new(4, EvictPolicy::Lru, 0)),
                ep_ranks: 4,
                ..CpuOptions::default()
            },
        );
        touch_experts(&be, &[0, 2, 4, 6]); // one expert per rank
        let rcs = Backend::residency_rank_counters(&be, 0).unwrap();
        assert_eq!(rcs.len(), 4);
        for rc in &rcs {
            assert_eq!(rc.misses, 1, "each rank pages in exactly its own expert");
        }
        // expert 1 shares rank 0 with expert 0: the eviction stays inside
        // rank 0's shard instead of victimizing another rank's resident
        touch_experts(&be, &[1]);
        let rcs = Backend::residency_rank_counters(&be, 0).unwrap();
        assert_eq!(rcs[0].misses, 2);
        assert_eq!(rcs[0].evictions, 1);
        for rc in rcs.iter().skip(1) {
            assert_eq!(rc.evictions, 0, "eviction leaked across ranks");
        }
        // the aggregate is the sum of the rank ledgers
        let agg = Backend::residency_counters(&be, 0).unwrap();
        assert_eq!(agg.misses, rcs.iter().map(|c| c.misses).sum::<u64>());
        assert_eq!(agg.evictions, 1);
        // the routing view concatenates per-rank resident masks
        let view = Backend::residency_view(&be, 0).unwrap();
        assert!(view[1] && !view[0], "rank 0 holds expert 1 after the eviction");
        assert!(view[2] && view[4] && view[6]);
        // per-rank residency executes bitwise like the eager pack
        let plain = backend_ep(4, 1);
        let c = plain.config().clone();
        let hidden = vec![0.1f32; c.d_model];
        let mut combine = vec![0.0f32; c.n_experts];
        combine[3] = 1.0;
        let a = plain.moe_apply(0, &hidden, &combine, &[3]).unwrap();
        let r = be.moe_apply(0, &hidden, &combine, &[3]).unwrap();
        assert_eq!(a, r, "per-rank residency changed the math");
    }

    #[test]
    fn mismatched_rank_partition_is_rejected() {
        use crate::moe::policy::route;
        let be = backend_ep(4, 1);
        let c = be.config().clone();
        let scores =
            ScoreMatrix::new(2, c.n_experts, vec![1.0 / c.n_experts as f32; 2 * c.n_experts]);
        let live = vec![true; 2];
        let d = route(
            Policy::Ep { k0: 1, k: 2, ranks: 2, topup: 0, alpha: 0.0 },
            &RoutingInput::new(&scores, &live, true),
        );
        let groups = ExpertGroups::from_decision(&d);
        let ids: Vec<i32> = d.active.iter().map(|&e| e as i32).collect();
        let hidden = vec![0.1f32; 2 * c.d_model];
        let step = RoutedStep { groups: &groups, combine: &d.combine, ids: &ids };
        let err = be.moe_apply_routed(0, &hidden, &step).unwrap_err();
        assert!(
            err.to_string().contains("ranks"),
            "mismatched sharding must fail loudly, got {err}"
        );
    }

    #[test]
    #[should_panic(expected = "requires grouped dispatch")]
    fn ep_rejects_gather_mode() {
        CpuBackend::synthetic_with(
            ModelConfig::preset("tiny").unwrap(),
            0,
            CpuOptions {
                dispatch: DispatchMode::Gather,
                threads: 1,
                ep_ranks: 2,
                ..CpuOptions::default()
            },
        );
    }

    #[test]
    #[should_panic(expected = "ep_ranks=0")]
    fn ep_rejects_zero_ranks() {
        CpuBackend::synthetic_with(
            ModelConfig::preset("tiny").unwrap(),
            0,
            CpuOptions {
                dispatch: DispatchMode::Grouped,
                threads: 1,
                ep_ranks: 0,
                ..CpuOptions::default()
            },
        );
    }

    #[test]
    fn grouped_scratch_reaches_steady_state() {
        use crate::util::arena::thread_arena_fresh_allocs;
        let be = backend_with(DispatchMode::Grouped, 1);
        let c = be.config().clone();
        let (b, n, d) = (4usize, c.n_experts, c.d_model);
        let hidden = vec![0.05f32; b * d];
        // warmup: dense combine maximizes every group, sizing all scratch
        let combine_full = vec![1.0f32 / n as f32; b * n];
        let all_ids: Vec<i32> = (0..n as i32).collect();
        let mut cache = be.new_cache(b).unwrap();
        let pos = vec![0i32; b];
        for _ in 0..3 {
            be.layer_pre(0, &hidden, &mut cache, &pos).unwrap();
            be.moe_apply(0, &hidden, &combine_full, &all_ids).unwrap();
        }
        let pool0 = be.scratch_fresh_allocs();
        let thread0 = thread_arena_fresh_allocs();
        for _ in 0..8 {
            be.layer_pre(0, &hidden, &mut cache, &pos).unwrap();
            be.moe_apply(0, &hidden, &combine_full, &all_ids).unwrap();
        }
        assert_eq!(
            be.scratch_fresh_allocs(),
            pool0,
            "shared scratch allocated after warmup"
        );
        assert_eq!(
            thread_arena_fresh_allocs(),
            thread0,
            "thread arena allocated after warmup"
        );
    }

    fn backend_dtype(dtype: PanelDtype, threads: usize) -> CpuBackend {
        CpuBackend::synthetic_with(
            ModelConfig::preset("tiny").unwrap(),
            0,
            CpuOptions { threads, panel_dtype: dtype, ..CpuOptions::default() },
        )
    }

    #[test]
    fn quantized_panels_execute_close_to_f32() {
        let f32be = backend_with(DispatchMode::Grouped, 1);
        let c = f32be.config().clone();
        let (b, n) = (4usize, c.n_experts);
        let hidden: Vec<f32> =
            (0..b * c.d_model).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let mut combine = vec![0.0f32; b * n];
        combine[0] = 0.7;
        combine[1] = 0.3;
        combine[n + 1] = 0.5;
        combine[n + 4] = 0.5;
        combine[2 * n + 4] = 1.0;
        combine[3 * n + 7] = 1.0;
        let ids = [0i32, 1, 4, 7];
        let want = f32be.moe_apply(0, &hidden, &combine, &ids).unwrap();
        let scale = want.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1.0);
        // tolerances are relative to the output magnitude: bf16 keeps 8
        // mantissa bits (~2^-9 per weight), int8 rounds to half a scale
        // step per weight; both accumulate over two D·H GEMMs
        for (dtype, tol) in [(PanelDtype::Bf16, 0.05f32), (PanelDtype::Int8, 0.2f32)] {
            let be = backend_dtype(dtype, 1);
            assert_eq!(be.panel_dtype(), dtype);
            let got = be.moe_apply(0, &hidden, &combine, &ids).unwrap();
            let mut max_err = 0.0f32;
            for (&w, &g) in want.iter().zip(got.iter()) {
                assert!(g.is_finite());
                max_err = max_err.max((w - g).abs());
            }
            assert!(
                max_err <= tol * scale,
                "{}: max err {max_err} > {} (scale {scale})",
                dtype.label(),
                tol * scale
            );
        }
    }

    fn backend_res_dtype(dtype: PanelDtype) -> CpuBackend {
        use crate::residency::EvictPolicy;
        CpuBackend::synthetic_with(
            ModelConfig::preset("tiny").unwrap(),
            0,
            CpuOptions {
                threads: 1,
                residency: Some(ResidencyConfig::new(2, EvictPolicy::Lru, 0)),
                panel_dtype: dtype,
                ..CpuOptions::default()
            },
        )
    }

    #[test]
    fn bytes_paged_tracks_panel_dtype() {
        // the residency ledger must charge the panel's storage dtype, not
        // a hard-coded f32 size — the offload-economics honesty property
        let paged = |dtype| {
            let be = backend_res_dtype(dtype);
            touch_experts(&be, &[0]);
            Backend::residency_stats(&be).unwrap().counters.bytes_paged
        };
        let f32b = paged(PanelDtype::F32);
        let bf16b = paged(PanelDtype::Bf16);
        let i8b = paged(PanelDtype::Int8);
        assert_eq!(f32b, 2 * bf16b, "bf16 panels are exactly half the f32 bytes");
        let ratio = f32b as f64 / i8b as f64;
        assert!(ratio >= 3.5, "int8 page-in bytes ratio {ratio} < 3.5");
    }

    #[test]
    fn concurrent_rank_execution_matches_serial_and_measures_walls() {
        let serial = backend_ep(2, 1);
        let conc = backend_ep(2, 4);
        assert_eq!(conc.rank_pools.len(), 2, "threaded EP backend builds per-rank pools");
        let c = serial.config().clone();
        let (b, n) = (4usize, c.n_experts);
        let hidden: Vec<f32> =
            (0..b * c.d_model).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let mut combine = vec![0.0f32; b * n];
        combine[0] = 0.7;
        combine[1] = 0.3;
        combine[n + 1] = 0.5;
        combine[n + 6] = 0.5;
        combine[2 * n + 4] = 1.0;
        combine[3 * n + 7] = 1.0;
        let ids = [0i32, 1, 4, 6, 7];
        let want = serial.moe_apply(0, &hidden, &combine, &ids).unwrap();
        let got = conc.moe_apply(0, &hidden, &combine, &ids).unwrap();
        for (&w, &g) in want.iter().zip(got.iter()) {
            assert!((w - g).abs() < 1e-6, "concurrent ranks diverged: {w} vs {g}");
        }
        // both ranks executed work and report a measured wall time
        let walls = Backend::rank_wall_us(&conc);
        assert_eq!(walls.len(), 2);
        assert!(walls.iter().all(|&w| w > 0.0), "rank walls not measured: {walls:?}");
        // the serial path measures per-rank walls too
        let walls = Backend::rank_wall_us(&serial);
        assert_eq!(walls.len(), 2);
        assert!(walls.iter().all(|&w| w > 0.0), "serial rank walls: {walls:?}");
    }

    #[test]
    fn simd_kernel_mode_matches_scalar_backend() {
        // on a non-AVX2 host SIMD degrades to scalar and this is bitwise;
        // on AVX2 the ≤1e-4 equivalence bound applies (the same bound
        // tests/kernel_equivalence.rs pins per kernel)
        let scalar = backend_with(DispatchMode::Grouped, 1);
        let simd = CpuBackend::synthetic_with(
            ModelConfig::preset("tiny").unwrap(),
            0,
            CpuOptions { threads: 1, kernels: KernelMode::Simd, ..CpuOptions::default() },
        );
        assert_eq!(simd.kernel_mode(), KernelMode::Simd);
        let c = scalar.config().clone();
        let b = 4usize;
        let mut cache_s = scalar.new_cache(b).unwrap();
        let mut cache_v = simd.new_cache(b).unwrap();
        let h_s = scalar.embed(&[5, 100, 200, 400]).unwrap();
        let pos = vec![0i32; b];
        let pre_s = scalar.layer_pre(0, &h_s, &mut cache_s, &pos).unwrap();
        let pre_v = simd.layer_pre(0, &h_s, &mut cache_v, &pos).unwrap();
        for (&a, &z) in pre_s.scores.iter().zip(pre_v.scores.iter()) {
            assert!((a - z).abs() < 1e-4, "router scores diverged: {a} vs {z}");
        }
        let n = c.n_experts;
        let mut combine = vec![0.0f32; b * n];
        combine[0] = 0.7;
        combine[1] = 0.3;
        combine[n + 4] = 1.0;
        let ids = [0i32, 1, 4];
        let want = scalar.moe_apply(0, &pre_s.h, &combine, &ids).unwrap();
        let got = simd.moe_apply(0, &pre_v.h, &combine, &ids).unwrap();
        for (&w, &g) in want.iter().zip(got.iter()) {
            assert!((w - g).abs() < 1e-3, "simd moe diverged: {w} vs {g}");
        }
    }
}
