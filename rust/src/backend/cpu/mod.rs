//! Hermetic pure-Rust CPU backend.
//!
//! Mirrors the JAX model (`python/compile/model.py`) stage for stage using
//! the kernels in [`kernels`]: embed, RoPE decode attention over the
//! slot-stable KV cache, router score computation, and the expert FFN.
//!
//! The MoE stage runs in one of two dispatch modes
//! ([`DispatchMode`], a constructor flag):
//!
//! - **Grouped** (default): token-grouped expert dispatch — each active
//!   expert's routed rows are gathered into a contiguous mini-batch, run
//!   through pre-packed weight panels ([`kernels::PackedMat`]), and
//!   scatter-added back weighted by combine. Per-step work is
//!   `Σ_e |tokens(e)| · 3DH` (the routed load), expert groups and
//!   attention batch rows execute in parallel over a
//!   [`crate::util::threadpool::ThreadPool`], and all kernel scratch
//!   comes from reusable arenas ([`crate::util::arena`]) so the hot loop
//!   performs no per-step heap allocation once warm.
//! - **Gather**: the original gathered-kernel oracle — every listed
//!   expert runs full-batch GEMMs (`T_bucket · B · 3DH` work), matching
//!   the gathered device kernel's cost model. Kept as the golden-pinned
//!   correctness reference; the two modes agree within float tolerance
//!   (see `rust/tests/dispatch_equivalence.rs`).
//!
//! Weights come from [`CpuBackend::synthetic`], the Rust port of
//! `python/compile/weights.py`: seeded-random with *structure* — token
//! embeddings carry a domain component and router columns carry per-expert
//! domain affinities — so router softmax distributions have realistic
//! concentration and domain-correlated expert choice, the two properties
//! OEA's phases interact with. Quality is always measured relative to
//! vanilla routing of the same model, exactly the quantity the paper
//! sweeps, so no pretrained checkpoint is needed.

pub mod kernels;

use std::sync::Mutex;

use crate::backend::{Backend, LayerPre, Prefilled};
use crate::config::ModelConfig;
use crate::moe::dispatch::{ExpertGroups, RoutedStep};
use crate::moe::policy::{self, Policy, RoutingInput};
use crate::moe::ScoreMatrix;
use crate::util::arena::{with_thread_arena, ScratchPool};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

use kernels::PackedMat;

/// How `moe_apply` executes the expert FFN. See the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DispatchMode {
    /// Token-grouped dispatch (work ∝ routed load) — the fast default.
    #[default]
    Grouped,
    /// Full-batch gathered kernel (work ∝ T bucket × B) — the oracle.
    Gather,
}

/// Construction options for [`CpuBackend::synthetic_with`].
#[derive(Debug, Clone, Copy)]
pub struct CpuOptions {
    pub dispatch: DispatchMode,
    /// Worker threads for expert groups and attention rows: `0` = one
    /// per available core, `1` = run inline (no pool).
    pub threads: usize,
}

impl Default for CpuOptions {
    fn default() -> Self {
        CpuOptions { dispatch: DispatchMode::Grouped, threads: 0 }
    }
}

impl CpuOptions {
    /// Environment overrides for benches and A/B runs:
    /// `OEA_DISPATCH=grouped|gather`, `OEA_THREADS=<n>`. Panics on
    /// unrecognized values — a typo must not silently measure the wrong
    /// dispatch mode.
    pub fn from_env() -> CpuOptions {
        let mut o = CpuOptions::default();
        if let Ok(v) = std::env::var("OEA_DISPATCH") {
            o.dispatch = match v.trim().to_ascii_lowercase().as_str() {
                "gather" => DispatchMode::Gather,
                "grouped" => DispatchMode::Grouped,
                other => panic!("OEA_DISPATCH={other:?}: expected grouped|gather"),
            };
        }
        if let Ok(v) = std::env::var("OEA_THREADS") {
            o.threads = v
                .trim()
                .parse::<usize>()
                .unwrap_or_else(|_| panic!("OEA_THREADS={v:?}: not an integer"));
        }
        o
    }
}

/// One transformer layer's weights (shapes as in `weights.py`).
pub struct LayerWeights {
    /// `[D, Hq*hd]`
    pub wq: Vec<f32>,
    /// `[D, Hkv*hd]`
    pub wk: Vec<f32>,
    /// `[D, Hkv*hd]`
    pub wv: Vec<f32>,
    /// `[Hq*hd, D]`
    pub wo: Vec<f32>,
    /// `[D]`
    pub n1: Vec<f32>,
    /// `[D]`
    pub n2: Vec<f32>,
    /// `[D, N]`
    pub router: Vec<f32>,
    /// `[N, D, H]`
    pub wg: Vec<f32>,
    /// `[N, D, H]`
    pub wu: Vec<f32>,
    /// `[N, H, D]`
    pub wd: Vec<f32>,
}

/// Pre-packed expert panels of one layer (grouped mode only).
struct PackedLayer {
    wg: PackedMat,
    wu: PackedMat,
    wd: PackedMat,
}

/// Per-layer KV cache of a decode batch: `[2, bucket, S, Hkv, hd]` per
/// layer (K at index 0, V at index 1 — the PJRT layout, so repack logic
/// and tests transfer unchanged).
pub struct CpuKvCache {
    pub bucket: usize,
    pub layers: Vec<Vec<f32>>,
}

/// A prefilled sequence's per-layer KV rows, each `[S, Hkv, hd]`.
pub struct CpuKvRows {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

pub struct CpuBackend {
    cfg: ModelConfig,
    /// `[V, D]`
    pub embed_w: Vec<f32>,
    /// `[D, V]`
    pub unembed_w: Vec<f32>,
    /// `[D]`
    pub final_norm: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    /// pre-transposed/padded expert panels, one per layer (grouped mode)
    packed: Vec<PackedLayer>,
    mode: DispatchMode,
    /// worker pool for expert groups / attention rows (None = inline)
    pool: Option<ThreadPool>,
    /// shared scratch for buffers that cross threads or live across one
    /// backend call (hidden-state temporaries, partial accumulators)
    scratch: ScratchPool,
    /// Cumulative routed (nonzero-combine) token-expert assignments per
    /// expert id (telemetry for load-balance analysis; counts decode and
    /// prefill work alike).
    expert_load: Mutex<Vec<u64>>,
}

fn gauss(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32).collect()
}

fn scaled(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() as f32 * scale).collect()
}

/// Contiguous group ranges balanced by routed-row count, preserving the
/// ascending-expert order (so chunked execution sums in the same order
/// as serial).
fn chunk_groups(groups: &ExpertGroups, workers: usize) -> Vec<(usize, usize)> {
    let ngroups = groups.len();
    let nchunks = workers.min(ngroups).max(1);
    let target = groups.routed_tokens().div_ceil(nchunks).max(1);
    let mut out = Vec::with_capacity(nchunks);
    let mut start = 0;
    let mut acc = 0;
    for gi in 0..ngroups {
        acc += groups.group(gi).rows.len();
        if acc >= target || gi == ngroups - 1 {
            out.push((start, gi + 1));
            start = gi + 1;
            acc = 0;
        }
    }
    out
}

impl CpuBackend {
    /// Structured synthetic weights (the Rust port of `weights.py::init`)
    /// with default options: grouped dispatch, one worker per core.
    /// Deterministic in `(cfg, seed)` — the dispatch mode never changes
    /// the weights.
    pub fn synthetic(cfg: ModelConfig, seed: u64) -> CpuBackend {
        Self::synthetic_with(cfg, seed, CpuOptions::default())
    }

    /// Structured synthetic weights with explicit dispatch/threading
    /// options ([`CpuOptions`]).
    pub fn synthetic_with(cfg: ModelConfig, seed: u64, opts: CpuOptions) -> CpuBackend {
        let mut rng = Rng::new(seed ^ 0x5EED_CAFE_F00D);
        let (d, v, n, h) = (cfg.d_model, cfg.vocab, cfg.n_experts, cfg.d_expert);
        let (qd, kvd, nd) = (cfg.q_dim(), cfg.kv_dim(), cfg.n_domains);

        // unit-norm domain centers in embedding space
        let mut centers = gauss(&mut rng, nd * d);
        for c in centers.chunks_exact_mut(d) {
            let norm = c.iter().map(|&x| x * x).sum::<f32>().sqrt().max(1e-6);
            for x in c.iter_mut() {
                *x /= norm;
            }
        }

        // embedding: domain component (band-structured token->domain
        // affinity, the offline stand-in for corpus co-occurrence) + noise,
        // unit-RMS rows
        let mut embed_w = scaled(&mut rng, v * d, 0.5);
        for (t, row) in embed_w.chunks_exact_mut(d).enumerate() {
            let primary = if t < 3 || v <= 3 {
                None
            } else {
                Some(((t - 3) * nd / (v - 3)).min(nd - 1))
            };
            for (dom, center) in centers.chunks_exact(d).enumerate() {
                let aff = match primary {
                    Some(p) if p == dom => 0.7,
                    Some(_) => 0.3 / (nd.max(2) - 1) as f32,
                    None => 1.0 / nd as f32,
                };
                for (x, &c) in row.iter_mut().zip(center.iter()) {
                    *x += aff * c;
                }
            }
            let ms = row.iter().map(|&x| x * x).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms.sqrt() + 1e-6);
            for x in row.iter_mut() {
                *x *= inv;
            }
        }

        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        let unembed_w = scaled(&mut rng, d * v, inv_sqrt_d);
        let final_norm = vec![1.0f32; d];

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            // expert -> domain assignment: round-robin, shuffled
            let mut dom: Vec<usize> = (0..n).map(|e| e % nd).collect();
            rng.shuffle(&mut dom);
            // router: per-expert domain affinity + idiosyncratic component
            let (beta, gamma) = (2.0 * inv_sqrt_d, inv_sqrt_d);
            let mut router = vec![0.0f32; d * n];
            for (e, &de) in dom.iter().enumerate() {
                let center = &centers[de * d..(de + 1) * d];
                for (dd, &c) in center.iter().enumerate() {
                    router[dd * n + e] = beta * c + gamma * rng.gaussian() as f32;
                }
            }
            layers.push(LayerWeights {
                wq: scaled(&mut rng, d * qd, inv_sqrt_d),
                wk: scaled(&mut rng, d * kvd, inv_sqrt_d),
                wv: scaled(&mut rng, d * kvd, inv_sqrt_d),
                wo: scaled(&mut rng, qd * d, 0.5 / (qd as f32).sqrt()),
                n1: vec![1.0f32; d],
                n2: vec![1.0f32; d],
                router,
                wg: scaled(&mut rng, n * d * h, inv_sqrt_d),
                wu: scaled(&mut rng, n * d * h, inv_sqrt_d),
                wd: scaled(&mut rng, n * h * d, 0.5 / (h as f32).sqrt()),
            });
        }

        let packed = match opts.dispatch {
            DispatchMode::Grouped => layers
                .iter()
                .map(|lw| PackedLayer {
                    wg: PackedMat::pack(&lw.wg, n, d, h),
                    wu: PackedMat::pack(&lw.wu, n, d, h),
                    wd: PackedMat::pack(&lw.wd, n, h, d),
                })
                .collect(),
            DispatchMode::Gather => Vec::new(),
        };

        let workers = match opts.threads {
            0 => std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            t => t,
        };
        let pool = if workers > 1 { Some(ThreadPool::new(workers)) } else { None };

        CpuBackend {
            expert_load: Mutex::new(vec![0u64; n]),
            cfg,
            embed_w,
            unembed_w,
            final_norm,
            layers,
            packed,
            mode: opts.dispatch,
            pool,
            scratch: ScratchPool::new(),
        }
    }

    pub fn dispatch_mode(&self) -> DispatchMode {
        self.mode
    }

    /// Snapshot of cumulative per-expert routed-token counts.
    pub fn expert_loads(&self) -> Vec<u64> {
        self.expert_load.lock().unwrap().clone()
    }

    pub fn reset_expert_loads(&self) {
        for x in self.expert_load.lock().unwrap().iter_mut() {
            *x = 0;
        }
    }

    /// Fresh-allocation count of the backend's shared scratch pool
    /// (stable across steps once warm; per-thread kernel arenas are
    /// tracked separately via `util::arena::thread_arena_fresh_allocs`).
    pub fn scratch_fresh_allocs(&self) -> u64 {
        self.scratch.fresh_allocs()
    }

    /// `S * Hkv * hd` — one slot's cache row length.
    fn row_len(&self) -> usize {
        self.cfg.s_max * self.cfg.n_kv_heads * self.cfg.head_dim
    }

    /// Decode attention over the updated cache, expert rows fanned out
    /// over the pool (per-row math is chunk-invariant, so any split is
    /// bitwise-identical to serial).
    fn attention(&self, q: &[f32], kc: &[f32], vc: &[f32], b: usize, pos: &[i32], out: &mut [f32]) {
        let c = &self.cfg;
        let (hq, hkv, hd) = (c.n_q_heads, c.n_kv_heads, c.head_dim);
        let s_max = c.s_max;
        let row = hq * hd;
        let workers = self.pool.as_ref().map(|p| p.size()).unwrap_or(1);
        let nchunks = workers.min(b).max(1);
        if nchunks <= 1 {
            with_thread_arena(|arena| {
                let mut logits = arena.take(s_max);
                kernels::decode_attention_rows(
                    q, kc, vc, s_max, hq, hkv, hd, pos, 0, out, &mut logits,
                );
                arena.put(logits);
            });
            return;
        }
        let rows_per = b.div_ceil(nchunks);
        let items: Vec<(usize, &mut [f32])> = out
            .chunks_mut(rows_per * row)
            .enumerate()
            .map(|(ci, chunk)| (ci * rows_per, chunk))
            .collect();
        self.pool.as_ref().unwrap().scoped_map(items, |(start, chunk): (usize, &mut [f32])| {
            with_thread_arena(|arena| {
                let mut logits = arena.take(s_max);
                kernels::decode_attention_rows(
                    q, kc, vc, s_max, hq, hkv, hd, pos, start, chunk, &mut logits,
                );
                arena.put(logits);
            });
        });
    }

    /// Grouped-dispatch expert FFN + residual: `hidden + Σ_groups ...`.
    fn moe_apply_grouped(
        &self,
        l: usize,
        hidden: &[f32],
        groups: &ExpertGroups,
    ) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let (d, n) = (c.d_model, c.n_experts);
        let b = hidden.len() / d;
        if groups.b != b || groups.n_experts != n {
            return Err(Error::Engine(format!(
                "moe groups shape [{}x{}] != batch [{}x{}]",
                groups.b, groups.n_experts, b, n
            )));
        }
        for grp in groups.iter() {
            if grp.expert >= n {
                return Err(Error::Engine(format!(
                    "moe group expert {} out of range",
                    grp.expert
                )));
            }
        }
        let lw = &self.layers[l];
        let pk = &self.packed[l];
        let mut hn = self.scratch.take(b * d);
        kernels::rmsnorm_into(hidden, &lw.n2, d, c.rms_eps, &mut hn);
        let mut acc = self.scratch.take(b * d);
        let ngroups = groups.len();
        let workers = self.pool.as_ref().map(|p| p.size()).unwrap_or(1);
        if workers <= 1 || ngroups <= 1 {
            with_thread_arena(|arena| {
                kernels::moe_ffn_groups(
                    &hn, &pk.wg, &pk.wu, &pk.wd, groups, 0, ngroups, &mut acc, arena,
                );
            });
        } else {
            let chunks = chunk_groups(groups, workers);
            let scratch = &self.scratch;
            let hn_ref = &hn;
            let pool = self.pool.as_ref().unwrap();
            let partials = pool.scoped_map(chunks, |(g0, g1): (usize, usize)| {
                let mut part = scratch.take(b * d);
                with_thread_arena(|arena| {
                    kernels::moe_ffn_groups(
                        hn_ref, &pk.wg, &pk.wu, &pk.wd, groups, g0, g1, &mut part, arena,
                    );
                });
                part
            });
            // reduce in chunk order == ascending-expert order (see
            // chunk_groups). Deterministic for a fixed worker count; a
            // token whose 3+ experts straddle a chunk boundary sums with
            // different float parenthesization than serial, so across
            // thread counts agreement is to rounding (~ulp), not bitwise.
            for part in partials {
                for (o, &pv) in acc.iter_mut().zip(part.iter()) {
                    *o += pv;
                }
                self.scratch.put(part);
            }
        }
        {
            let mut load = self.expert_load.lock().unwrap();
            for grp in groups.iter() {
                load[grp.expert] += grp.rows.len() as u64;
            }
        }
        let mut out = hidden.to_vec();
        for (o, &yv) in out.iter_mut().zip(acc.iter()) {
            *o += yv;
        }
        self.scratch.put(acc);
        self.scratch.put(hn);
        Ok(out)
    }
}

impl Backend for CpuBackend {
    type Cache = CpuKvCache;
    type Rows = CpuKvRows;

    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn label(&self) -> &'static str {
        "cpu"
    }

    fn new_cache(&self, bucket: usize) -> Result<CpuKvCache> {
        let layers = (0..self.cfg.n_layers)
            .map(|_| vec![0.0f32; 2 * bucket * self.row_len()])
            .collect();
        Ok(CpuKvCache { bucket, layers })
    }

    fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (d, v) = (self.cfg.d_model, self.cfg.vocab);
        let mut out = vec![0.0f32; tokens.len() * d];
        for (i, &t) in tokens.iter().enumerate() {
            // clamp like jnp.take's default out-of-bounds behaviour
            let t = (t.max(0) as usize).min(v - 1);
            out[i * d..(i + 1) * d].copy_from_slice(&self.embed_w[t * d..(t + 1) * d]);
        }
        Ok(out)
    }

    fn layer_pre(
        &self,
        l: usize,
        hidden: &[f32],
        cache: &mut CpuKvCache,
        pos: &[i32],
    ) -> Result<LayerPre> {
        let c = &self.cfg;
        let b = pos.len();
        if hidden.len() != b * c.d_model || cache.bucket != b {
            return Err(Error::Engine(format!(
                "layer_pre shape mismatch: hidden {} pos {} bucket {}",
                hidden.len(),
                b,
                cache.bucket
            )));
        }
        let lw = &self.layers[l];
        let (d, qd, kvd) = (c.d_model, c.q_dim(), c.kv_dim());
        let (hq, hkv, hd) = (c.n_q_heads, c.n_kv_heads, c.head_dim);

        let mut h1 = self.scratch.take(b * d);
        kernels::rmsnorm_into(hidden, &lw.n1, d, c.rms_eps, &mut h1);
        let mut q = self.scratch.take(b * qd);
        let mut k = self.scratch.take(b * kvd);
        let mut v = self.scratch.take(b * kvd);
        kernels::matmul_into(&h1, &lw.wq, b, d, qd, &mut q);
        kernels::matmul_into(&h1, &lw.wk, b, d, kvd, &mut k);
        kernels::matmul_into(&h1, &lw.wv, b, d, kvd, &mut v);
        self.scratch.put(h1);
        kernels::rope(&mut q, hq, hd, pos, c.rope_theta);
        kernels::rope(&mut k, hkv, hd, pos, c.rope_theta);

        // slot-stable cache append: row b's slot pos[b] gets this step's K/V
        let row = self.row_len();
        let half = b * row;
        let cl = &mut cache.layers[l];
        for i in 0..b {
            let slot = (pos[i].max(0) as usize).min(c.s_max - 1);
            let dst = i * row + slot * kvd;
            cl[dst..dst + kvd].copy_from_slice(&k[i * kvd..(i + 1) * kvd]);
            cl[half + dst..half + dst + kvd].copy_from_slice(&v[i * kvd..(i + 1) * kvd]);
        }
        self.scratch.put(k);
        self.scratch.put(v);

        // attention over the UPDATED cache (model.py layer_pre semantics),
        // batch rows fanned out over the pool
        let (kc, vc) = cl.split_at(half);
        let mut attn = self.scratch.take(b * qd);
        self.attention(&q, kc, vc, b, pos, &mut attn);
        self.scratch.put(q);
        let mut ao = self.scratch.take(b * d);
        kernels::matmul_into(&attn, &lw.wo, b, qd, d, &mut ao);
        self.scratch.put(attn);
        let mut h_out = hidden.to_vec();
        for (o, &a) in h_out.iter_mut().zip(ao.iter()) {
            *o += a;
        }
        self.scratch.put(ao);
        let scores =
            kernels::router_scores(&h_out, &lw.n2, &lw.router, b, d, c.n_experts, c.rms_eps);
        Ok(LayerPre { h: h_out, scores })
    }

    fn moe_apply(
        &self,
        l: usize,
        hidden: &[f32],
        combine: &[f32],
        ids: &[i32],
    ) -> Result<Vec<f32>> {
        let c = &self.cfg;
        let (d, h, n) = (c.d_model, c.d_expert, c.n_experts);
        let b = hidden.len() / d;
        if combine.len() != b * n {
            return Err(Error::Engine(format!(
                "moe_apply combine len {} != {}x{}",
                combine.len(),
                b,
                n
            )));
        }
        for &id in ids {
            if id < 0 || id as usize >= n {
                return Err(Error::Engine(format!("moe_apply expert id {id} out of range")));
            }
        }
        match self.mode {
            DispatchMode::Grouped => {
                let groups = ExpertGroups::from_combine(combine, ids, b, n);
                self.moe_apply_grouped(l, hidden, &groups)
            }
            DispatchMode::Gather => {
                let lw = &self.layers[l];
                let mut hn = self.scratch.take(b * d);
                kernels::rmsnorm_into(hidden, &lw.n2, d, c.rms_eps, &mut hn);
                let mut y = self.scratch.take(b * d);
                with_thread_arena(|arena| {
                    kernels::moe_ffn_gather_into(
                        &hn, &lw.wg, &lw.wu, &lw.wd, combine, ids, b, d, h, n, &mut y, arena,
                    );
                });
                {
                    // telemetry: routed (nonzero-combine) tokens of the
                    // experts the kernel actually executed (those in
                    // `ids`), so the histogram matches grouped dispatch
                    // on identical inputs
                    let mut active = vec![false; n];
                    for &id in ids {
                        active[id as usize] = true;
                    }
                    let mut load = self.expert_load.lock().unwrap();
                    for rowc in combine.chunks_exact(n) {
                        for (e, &cv) in rowc.iter().enumerate() {
                            if active[e] && cv != 0.0 {
                                load[e] += 1;
                            }
                        }
                    }
                }
                let mut out = hidden.to_vec();
                for (o, &yv) in out.iter_mut().zip(y.iter()) {
                    *o += yv;
                }
                self.scratch.put(y);
                self.scratch.put(hn);
                Ok(out)
            }
        }
    }

    fn moe_apply_routed(&self, l: usize, hidden: &[f32], step: &RoutedStep) -> Result<Vec<f32>> {
        match self.mode {
            // the serving path: groups come straight from the routing
            // decision, no dense combine scan needed
            DispatchMode::Grouped => self.moe_apply_grouped(l, hidden, step.groups),
            DispatchMode::Gather => self.moe_apply(l, hidden, step.combine, step.ids),
        }
    }

    /// Final-norm + unembedding GEMM `[B, D] x [D, V]` — the largest
    /// single GEMM of a decode step. Batch rows fan out over the pool in
    /// micro-kernel-aligned chunks; per-row accumulation order is
    /// identical under any row split, so the parallel result is the same
    /// as serial (see `logits_parallel_matches_serial` in
    /// `tests/dispatch_equivalence.rs`).
    fn logits(&self, hidden: &[f32]) -> Result<Vec<f32>> {
        let (d, v) = (self.cfg.d_model, self.cfg.vocab);
        let b = hidden.len() / d;
        let mut hn = self.scratch.take(b * d);
        kernels::rmsnorm_into(hidden, &self.final_norm, d, self.cfg.rms_eps, &mut hn);
        let mut out = vec![0.0f32; b * v];
        let workers = self.pool.as_ref().map(|p| p.size()).unwrap_or(1);
        if workers <= 1 || b <= 4 {
            kernels::matmul_into(&hn, &self.unembed_w, b, d, v, &mut out);
        } else {
            // rows per chunk: even split across workers, rounded up to the
            // GEMM micro-kernel's 4-row pass so no chunk wastes a pass
            let rows_per = b.div_ceil(workers).div_ceil(4) * 4;
            let items: Vec<(&[f32], &mut [f32])> = hn
                .chunks(rows_per * d)
                .zip(out.chunks_mut(rows_per * v))
                .collect();
            let w = &self.unembed_w;
            self.pool.as_ref().unwrap().scoped_map(items, |(a, o): (&[f32], &mut [f32])| {
                kernels::matmul_into(a, w, o.len() / v, d, v, o);
            });
        }
        self.scratch.put(hn);
        Ok(out)
    }

    /// Teacher-forced prefill: the prompt runs through the decode path one
    /// token at a time with in-graph vanilla routing, which is *exactly*
    /// the decode pipeline's math — prefill/decode consistency holds by
    /// construction (the chunked-prefill fast path is a PJRT artifact
    /// concern; the reference backend favours exactness).
    fn prefill(&self, prompt: &[i32]) -> Result<Prefilled<CpuKvRows>> {
        let c = self.cfg.clone();
        if prompt.is_empty() {
            return Err(Error::Engine("empty prompt".into()));
        }
        if prompt.len() > c.s_max - 1 {
            return Err(Error::Engine(format!(
                "prompt of {} tokens exceeds s_max-1 = {}",
                prompt.len(),
                c.s_max - 1
            )));
        }
        let mut cache = self.new_cache(1)?;
        let mut last_hidden = Vec::new();
        for (t, &tok) in prompt.iter().enumerate() {
            let mut hidden = self.embed(&[tok])?;
            for l in 0..c.n_layers {
                let pre = self.layer_pre(l, &hidden, &mut cache, &[t as i32])?;
                let scores = ScoreMatrix::new(1, c.n_experts, pre.scores);
                let live = [true];
                let d = policy::route(
                    Policy::Vanilla { k: c.top_k },
                    &RoutingInput { scores: &scores, live: &live, mask_padding: true },
                );
                let ids: Vec<i32> = d.active.iter().map(|&e| e as i32).collect();
                hidden = self.moe_apply(l, &pre.h, &d.combine, &ids)?;
            }
            last_hidden = hidden;
        }
        let last_logits = self.logits(&last_hidden)?;
        let row = self.row_len();
        let mut k_rows = Vec::with_capacity(c.n_layers);
        let mut v_rows = Vec::with_capacity(c.n_layers);
        for cl in &cache.layers {
            k_rows.push(cl[..row].to_vec());
            v_rows.push(cl[row..2 * row].to_vec());
        }
        Ok(Prefilled {
            rows: CpuKvRows { k: k_rows, v: v_rows },
            n_tokens: prompt.len(),
            last_logits,
        })
    }

    fn install_rows(&self, cache: &mut CpuKvCache, slot: usize, rows: &CpuKvRows) -> Result<()> {
        let row = self.row_len();
        let b = cache.bucket;
        if slot >= b {
            return Err(Error::Engine(format!("slot {slot} out of bucket {b}")));
        }
        for (l, cl) in cache.layers.iter_mut().enumerate() {
            let half = b * row;
            cl[slot * row..(slot + 1) * row].copy_from_slice(&rows.k[l]);
            cl[half + slot * row..half + (slot + 1) * row].copy_from_slice(&rows.v[l]);
        }
        Ok(())
    }

    fn clear_slot(&self, cache: &mut CpuKvCache, slot: usize) -> Result<()> {
        let row = self.row_len();
        let b = cache.bucket;
        if slot >= b {
            return Err(Error::Engine(format!("slot {slot} out of bucket {b}")));
        }
        for cl in cache.layers.iter_mut() {
            let half = b * row;
            cl[slot * row..(slot + 1) * row].fill(0.0);
            cl[half + slot * row..half + (slot + 1) * row].fill(0.0);
        }
        Ok(())
    }

    fn repack(
        &self,
        cache: &CpuKvCache,
        old_bucket: usize,
        new_bucket: usize,
        mapping: &[Option<usize>],
    ) -> Result<CpuKvCache> {
        if cache.bucket != old_bucket || mapping.len() != old_bucket {
            return Err(Error::Engine("repack mapping/bucket mismatch".into()));
        }
        let row = self.row_len();
        let mut out = self.new_cache(new_bucket)?;
        for (l, cl) in cache.layers.iter().enumerate() {
            let fresh = &mut out.layers[l];
            for half in 0..2 {
                let src_base = half * old_bucket * row;
                let dst_base = half * new_bucket * row;
                for (i, m) in mapping.iter().enumerate() {
                    if let Some(j) = m {
                        if *j >= new_bucket {
                            return Err(Error::Engine(format!(
                                "repack target slot {j} out of bucket {new_bucket}"
                            )));
                        }
                        fresh[dst_base + j * row..dst_base + (j + 1) * row]
                            .copy_from_slice(&cl[src_base + i * row..src_base + (i + 1) * row]);
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> CpuBackend {
        CpuBackend::synthetic(ModelConfig::preset("tiny").unwrap(), 0)
    }

    fn backend_with(dispatch: DispatchMode, threads: usize) -> CpuBackend {
        CpuBackend::synthetic_with(
            ModelConfig::preset("tiny").unwrap(),
            0,
            CpuOptions { dispatch, threads },
        )
    }

    #[test]
    fn synthetic_weights_are_deterministic() {
        let a = backend();
        let b = backend();
        assert_eq!(a.embed_w, b.embed_w);
        assert_eq!(a.layers[0].router, b.layers[0].router);
        let c = CpuBackend::synthetic(ModelConfig::preset("tiny").unwrap(), 1);
        assert_ne!(a.embed_w, c.embed_w);
        // dispatch mode never changes the weights
        let g = backend_with(DispatchMode::Gather, 1);
        assert_eq!(a.embed_w, g.embed_w);
        assert_eq!(a.layers[1].wg, g.layers[1].wg);
    }

    #[test]
    fn router_scores_have_realistic_concentration() {
        // top-1 mass dominant but well below 1 — the property the OEA
        // phases interact with (weights.py's stated calibration target)
        let be = backend();
        let c = be.config().clone();
        let mut cache = be.new_cache(4).unwrap();
        let h = be.embed(&[5, 100, 200, 400]).unwrap();
        let pre = be.layer_pre(0, &h, &mut cache, &[0, 0, 0, 0]).unwrap();
        for row in pre.scores.chunks_exact(c.n_experts) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "softmax rows sum to 1, got {sum}");
            let top1 = row.iter().cloned().fold(0.0f32, f32::max);
            assert!(top1 > 1.5 / c.n_experts as f32, "flat router (top1 {top1})");
            assert!(top1 < 0.99, "collapsed router (top1 {top1})");
        }
    }

    #[test]
    fn expert_load_accounting_counts_assignments() {
        for be in [backend_with(DispatchMode::Grouped, 1), backend_with(DispatchMode::Gather, 1)]
        {
            let c = be.config().clone();
            let n = c.n_experts;
            let b = 2;
            let hidden = vec![0.1f32; b * c.d_model];
            let mut combine = vec![0.0f32; b * n];
            combine[0] = 0.6;
            combine[1] = 0.4;
            combine[n + 2] = 1.0;
            be.moe_apply(0, &hidden, &combine, &[0, 1, 2]).unwrap();
            let loads = be.expert_loads();
            assert_eq!(loads[0], 1);
            assert_eq!(loads[1], 1);
            assert_eq!(loads[2], 1);
            assert_eq!(loads.iter().sum::<u64>(), 3);
            be.reset_expert_loads();
            assert_eq!(be.expert_loads().iter().sum::<u64>(), 0);
        }
    }

    #[test]
    fn moe_rejects_out_of_range_ids() {
        for be in [backend_with(DispatchMode::Grouped, 1), backend_with(DispatchMode::Gather, 1)]
        {
            let c = be.config().clone();
            let hidden = vec![0.0f32; c.d_model];
            let combine = vec![0.0f32; c.n_experts];
            assert!(be.moe_apply(0, &hidden, &combine, &[c.n_experts as i32]).is_err());
        }
    }

    #[test]
    fn grouped_matches_gather_per_layer() {
        // one moe_apply under each mode (and threaded vs inline) agrees
        let grouped = backend_with(DispatchMode::Grouped, 1);
        let threaded = backend_with(DispatchMode::Grouped, 3);
        let gather = backend_with(DispatchMode::Gather, 1);
        let c = grouped.config().clone();
        let (b, n) = (4usize, c.n_experts);
        let hidden: Vec<f32> =
            (0..b * c.d_model).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
        let mut combine = vec![0.0f32; b * n];
        // tokens spread over experts, one token unrouted
        combine[0] = 0.7;
        combine[1] = 0.3;
        combine[n + 1] = 0.5;
        combine[n + 4] = 0.5;
        combine[2 * n + 4] = 1.0;
        let ids = [0i32, 1, 4, 6]; // 6 is active-but-unused padding
        let a = gather.moe_apply(1, &hidden, &combine, &ids).unwrap();
        let g1 = grouped.moe_apply(1, &hidden, &combine, &ids).unwrap();
        let g2 = threaded.moe_apply(1, &hidden, &combine, &ids).unwrap();
        for ((x, y), z) in a.iter().zip(g1.iter()).zip(g2.iter()) {
            assert!((x - y).abs() < 1e-4, "grouped {y} vs gather {x}");
            assert!((y - z).abs() < 1e-6, "threaded {z} vs inline {y}");
        }
        // the unrouted padding row passes through as pure residual
        assert_eq!(&g1[3 * c.d_model..], &hidden[3 * c.d_model..]);
    }

    #[test]
    fn grouped_scratch_reaches_steady_state() {
        use crate::util::arena::thread_arena_fresh_allocs;
        let be = backend_with(DispatchMode::Grouped, 1);
        let c = be.config().clone();
        let (b, n, d) = (4usize, c.n_experts, c.d_model);
        let hidden = vec![0.05f32; b * d];
        // warmup: dense combine maximizes every group, sizing all scratch
        let combine_full = vec![1.0f32 / n as f32; b * n];
        let all_ids: Vec<i32> = (0..n as i32).collect();
        let mut cache = be.new_cache(b).unwrap();
        let pos = vec![0i32; b];
        for _ in 0..3 {
            be.layer_pre(0, &hidden, &mut cache, &pos).unwrap();
            be.moe_apply(0, &hidden, &combine_full, &all_ids).unwrap();
        }
        let pool0 = be.scratch_fresh_allocs();
        let thread0 = thread_arena_fresh_allocs();
        for _ in 0..8 {
            be.layer_pre(0, &hidden, &mut cache, &pos).unwrap();
            be.moe_apply(0, &hidden, &combine_full, &all_ids).unwrap();
        }
        assert_eq!(
            be.scratch_fresh_allocs(),
            pool0,
            "shared scratch allocated after warmup"
        );
        assert_eq!(
            thread_arena_fresh_allocs(),
            thread0,
            "thread arena allocated after warmup"
        );
    }
}
