//! Deterministic fault injection and expert-health tracking.
//!
//! A [`FaultPlan`] (CLI `--faults`) describes a seeded chaos scenario as
//! `;`-separated clauses, e.g.
//!
//! ```text
//! pagein-fail:rate=0.05,seed=7;rank-stall:rank=2,after_steps=50,us=20000;expert-poison:layer=3,expert=11
//! ```
//!
//! The backend injects these at its existing hook points — page-in
//! failures and latency spikes in the residency layer, per-rank stalls
//! and outages in the EP dispatch path, NaN-poisoned expert outputs in
//! the grouped MoE FFN, and a one-shot panic for exercising the engine's
//! `catch_unwind` isolation. Every random draw comes from one seeded
//! [`Rng`], so a chaos run is replayable bit for bit.
//!
//! [`FaultState`] is the injection-time bookkeeping: it owns the plan,
//! the per-`(layer, expert)` health flags that feed
//! `Backend::health_view` (and from there the routing mask next to
//! `residency_view`), the bounded-jittered-retry schedule for failed
//! page-ins, injected-fault counters, and a bounded log of auditable
//! [`DegradationEvent`]s. An *empty* plan installs no state at all, so
//! the fault-free path stays bitwise-identical to a build that never
//! heard of faults (property-tested in `tests/chaos_properties.rs`).

use std::fmt;
use std::sync::Arc;

use crate::obs::{EventLog, Tracer, EVENTS_TID};
use crate::util::error::{Error, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Bernoulli page-in failure: each panel page-in attempt fails with
/// probability `rate`, drawn from a stream seeded by `seed`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PageinFail {
    pub rate: f64,
    pub seed: u64,
}

/// Page-in latency spike: each page-in sleeps `us` with probability
/// `rate` (a slow storage tier, not a failure — health never trips).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PageinDelay {
    pub us: u64,
    pub rate: f64,
}

/// Per-rank stall: once `after_steps` forward passes have run, every MoE
/// layer execution sleeps `us` inside rank `rank`'s work list (the other
/// ranks proceed; the step waits on the straggler, exactly EP semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankStall {
    pub rank: usize,
    pub after_steps: u64,
    pub us: u64,
}

/// Rank outage: once `after_steps` forward passes have run, every expert
/// homed on `rank` trips unhealthy on every layer — routing masks the
/// whole shard out and tokens piggyback onto surviving ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankDown {
    pub rank: usize,
    pub after_steps: u64,
}

/// Poisoned expert: expert `expert`'s FFN output on layer `layer` is
/// overwritten with NaN. The backend detects it, trips the expert's
/// health, and lets the NaN flow — the engine's non-finite logits guard
/// must retire the affected request without killing the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExpertPoison {
    pub layer: usize,
    pub expert: usize,
}

/// One-shot injected panic at the entry of layer `layer`'s MoE stage
/// once `after_steps` forward passes have run — the chaos probe for the
/// engine's per-step `catch_unwind` isolation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepPanic {
    pub layer: usize,
    pub after_steps: u64,
}

/// Rank recovery: once `after_steps` forward passes have run, every
/// tripped expert homed on `rank` is restored healthy on every layer —
/// the rolling-restart counterpart to [`RankDown`] (a replaced or
/// rebooted rank rejoining the serving set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RankUp {
    pub rank: usize,
    pub after_steps: u64,
}

/// Half-open probation for tripped experts: `steps` forward passes after
/// an expert trips, routing is allowed back (the expert re-enters the
/// health mask as HALF-OPEN). The first clean execution re-admits it
/// fully; a re-trip while half-open restarts the probation clock. Opt-in
/// via the `probation:steps=N` clause — without it, trips stay permanent
/// (the pre-existing pessimistic default, bitwise-unchanged).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Probation {
    pub steps: u64,
}

/// A parsed, seeded chaos scenario. `Default`/empty means "no faults" —
/// and the backend must treat that as bitwise-identical to having no
/// plan at all.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub pagein_fail: Option<PageinFail>,
    pub pagein_delay: Option<PageinDelay>,
    pub rank_stall: Vec<RankStall>,
    pub rank_down: Vec<RankDown>,
    pub rank_up: Vec<RankUp>,
    pub expert_poison: Vec<ExpertPoison>,
    pub step_panic: Option<StepPanic>,
    pub probation: Option<Probation>,
}

fn parse_kvs<'a>(clause: &'a str, body: &'a str) -> Result<Vec<(&'a str, &'a str)>> {
    let mut kvs = Vec::new();
    for part in body.split(',').filter(|p| !p.trim().is_empty()) {
        match part.split_once('=') {
            Some((k, v)) => kvs.push((k.trim(), v.trim())),
            None => {
                return Err(Error::Config(format!(
                    "fault clause {clause:?}: expected key=value, got {part:?}"
                )))
            }
        }
    }
    Ok(kvs)
}

fn kv_f64(clause: &str, kvs: &[(&str, &str)], key: &str) -> Result<Option<f64>> {
    match kvs.iter().find(|(k, _)| *k == key) {
        Some((_, v)) => v
            .parse::<f64>()
            .map(Some)
            .map_err(|_| Error::Config(format!("fault clause {clause:?}: {key}={v:?} not a number"))),
        None => Ok(None),
    }
}

fn kv_u64(clause: &str, kvs: &[(&str, &str)], key: &str) -> Result<Option<u64>> {
    match kvs.iter().find(|(k, _)| *k == key) {
        Some((_, v)) => v
            .parse::<u64>()
            .map(Some)
            .map_err(|_| {
                Error::Config(format!("fault clause {clause:?}: {key}={v:?} not an integer"))
            }),
        None => Ok(None),
    }
}

fn require<T>(clause: &str, key: &str, v: Option<T>) -> Result<T> {
    v.ok_or_else(|| Error::Config(format!("fault clause {clause:?}: missing required {key}=")))
}

fn check_keys(clause: &str, kvs: &[(&str, &str)], allowed: &[&str]) -> Result<()> {
    for (k, _) in kvs {
        if !allowed.contains(k) {
            return Err(Error::Config(format!(
                "fault clause {clause:?}: unknown key {k:?} (allowed: {})",
                allowed.join(", ")
            )));
        }
    }
    Ok(())
}

impl FaultPlan {
    /// Parse a `--faults` spec. The grammar is `;`-separated clauses of
    /// `name:key=val,key=val`; an empty spec is the empty plan. Unknown
    /// clause names, unknown keys, and malformed values are loud
    /// [`Error::Config`]s — a typo'd chaos plan must never silently run
    /// a clean baseline.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let (name, body) = clause.split_once(':').unwrap_or((clause, ""));
            let kvs = parse_kvs(clause, body)?;
            match name.trim() {
                "pagein-fail" => {
                    check_keys(clause, &kvs, &["rate", "seed"])?;
                    let rate = require(clause, "rate", kv_f64(clause, &kvs, "rate")?)?;
                    if !(0.0..=1.0).contains(&rate) {
                        return Err(Error::Config(format!(
                            "fault clause {clause:?}: rate={rate} must be in [0, 1]"
                        )));
                    }
                    plan.pagein_fail = Some(PageinFail {
                        rate,
                        seed: kv_u64(clause, &kvs, "seed")?.unwrap_or(0),
                    });
                }
                "pagein-delay" => {
                    check_keys(clause, &kvs, &["us", "rate"])?;
                    plan.pagein_delay = Some(PageinDelay {
                        us: require(clause, "us", kv_u64(clause, &kvs, "us")?)?,
                        rate: kv_f64(clause, &kvs, "rate")?.unwrap_or(1.0),
                    });
                }
                "rank-stall" => {
                    check_keys(clause, &kvs, &["rank", "after_steps", "us"])?;
                    plan.rank_stall.push(RankStall {
                        rank: require(clause, "rank", kv_u64(clause, &kvs, "rank")?)? as usize,
                        after_steps: kv_u64(clause, &kvs, "after_steps")?.unwrap_or(0),
                        us: require(clause, "us", kv_u64(clause, &kvs, "us")?)?,
                    });
                }
                "rank-down" => {
                    check_keys(clause, &kvs, &["rank", "after_steps"])?;
                    plan.rank_down.push(RankDown {
                        rank: require(clause, "rank", kv_u64(clause, &kvs, "rank")?)? as usize,
                        after_steps: kv_u64(clause, &kvs, "after_steps")?.unwrap_or(0),
                    });
                }
                "rank-up" => {
                    check_keys(clause, &kvs, &["rank", "after_steps"])?;
                    plan.rank_up.push(RankUp {
                        rank: require(clause, "rank", kv_u64(clause, &kvs, "rank")?)? as usize,
                        after_steps: kv_u64(clause, &kvs, "after_steps")?.unwrap_or(0),
                    });
                }
                "probation" => {
                    check_keys(clause, &kvs, &["steps"])?;
                    let steps = require(clause, "steps", kv_u64(clause, &kvs, "steps")?)?;
                    if steps == 0 {
                        return Err(Error::Config(format!(
                            "fault clause {clause:?}: steps must be >= 1 (omit the \
                             clause to keep trips permanent)"
                        )));
                    }
                    plan.probation = Some(Probation { steps });
                }
                "expert-poison" => {
                    check_keys(clause, &kvs, &["layer", "expert"])?;
                    plan.expert_poison.push(ExpertPoison {
                        layer: require(clause, "layer", kv_u64(clause, &kvs, "layer")?)? as usize,
                        expert: require(clause, "expert", kv_u64(clause, &kvs, "expert")?)?
                            as usize,
                    });
                }
                "step-panic" => {
                    check_keys(clause, &kvs, &["layer", "after_steps"])?;
                    plan.step_panic = Some(StepPanic {
                        layer: require(clause, "layer", kv_u64(clause, &kvs, "layer")?)? as usize,
                        after_steps: kv_u64(clause, &kvs, "after_steps")?.unwrap_or(0),
                    });
                }
                other => {
                    return Err(Error::Config(format!(
                        "unknown fault clause {other:?} (pagein-fail | pagein-delay | \
                         rank-stall | rank-down | rank-up | expert-poison | step-panic \
                         | probation)"
                    )))
                }
            }
        }
        Ok(plan)
    }

    pub fn is_empty(&self) -> bool {
        *self == FaultPlan::default()
    }

    /// Canonical re-rendering of the plan (the `/metrics` `faults.plan`
    /// field) — parse(label()) round-trips.
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if let Some(p) = &self.pagein_fail {
            parts.push(format!("pagein-fail:rate={},seed={}", p.rate, p.seed));
        }
        if let Some(p) = &self.pagein_delay {
            parts.push(format!("pagein-delay:us={},rate={}", p.us, p.rate));
        }
        for s in &self.rank_stall {
            parts.push(format!(
                "rank-stall:rank={},after_steps={},us={}",
                s.rank, s.after_steps, s.us
            ));
        }
        for d in &self.rank_down {
            parts.push(format!("rank-down:rank={},after_steps={}", d.rank, d.after_steps));
        }
        for u in &self.rank_up {
            parts.push(format!("rank-up:rank={},after_steps={}", u.rank, u.after_steps));
        }
        for p in &self.expert_poison {
            parts.push(format!("expert-poison:layer={},expert={}", p.layer, p.expert));
        }
        if let Some(p) = &self.step_panic {
            parts.push(format!("step-panic:layer={},after_steps={}", p.layer, p.after_steps));
        }
        if let Some(p) = &self.probation {
            parts.push(format!("probation:steps={}", p.steps));
        }
        parts.join(";")
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// Bounded jittered retry schedule for failed page-ins: attempt `a`
/// backs off `base_us << a` capped at `cap_us`, jittered into
/// `[backoff/2, backoff]` so retry storms decorrelate. After
/// `max_attempts` total attempts the expert trips unhealthy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    pub max_attempts: usize,
    pub base_us: u64,
    pub cap_us: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, base_us: 50, cap_us: 2_000 }
    }
}

/// Jittered backoff before retry attempt `attempt` (0-based: the wait
/// after the first failure is `attempt = 0`). Always in
/// `[cap/2 .. cap]`-bounded range: `backoff_us(a) <= cap_us` for every
/// `a`, and `>= base_us / 2` — the bounds `tests/chaos_properties.rs`
/// pins.
pub fn backoff_us(rng: &mut Rng, attempt: usize, pol: &RetryPolicy) -> u64 {
    let exp = pol
        .base_us
        .saturating_mul(1u64 << attempt.min(32))
        .min(pol.cap_us)
        .max(1);
    let half = exp / 2;
    half + (rng.f64() * (exp - half + 1) as f64) as u64
}

/// Which injected-fault mechanism caused a degradation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultClass {
    PageinFail,
    PageinDelay,
    RankStall,
    RankDown,
    RankUp,
    ExpertPoison,
    StepPanic,
    Reroute,
    Probation,
    /// a routing-parameter shift decided by the SLO control plane (the
    /// controller borrows this ledger shape for its own event log)
    SloControl,
}

impl FaultClass {
    pub fn label(&self) -> &'static str {
        match self {
            FaultClass::PageinFail => "pagein-fail",
            FaultClass::PageinDelay => "pagein-delay",
            FaultClass::RankStall => "rank-stall",
            FaultClass::RankDown => "rank-down",
            FaultClass::RankUp => "rank-up",
            FaultClass::ExpertPoison => "expert-poison",
            FaultClass::StepPanic => "step-panic",
            FaultClass::Reroute => "reroute",
            FaultClass::Probation => "probation",
            FaultClass::SloControl => "slo-control",
        }
    }
}

/// One auditable degradation decision — why routing (or serving) shifted.
#[derive(Debug, Clone)]
pub struct DegradationEvent {
    /// forward-pass count when the event fired
    pub step: u64,
    pub class: FaultClass,
    pub layer: Option<usize>,
    pub expert: Option<usize>,
    pub rank: Option<usize>,
    pub detail: String,
}

impl DegradationEvent {
    /// Args for the tracer's instant-event rendering of this ledger
    /// entry (the `/trace` view of the event bus).
    pub fn trace_args(&self) -> Vec<(&'static str, Json)> {
        let mut args = vec![("step", Json::num(self.step as f64))];
        if let Some(l) = self.layer {
            args.push(("layer", Json::num(l as f64)));
        }
        if let Some(e) = self.expert {
            args.push(("expert", Json::num(e as f64)));
        }
        if let Some(r) = self.rank {
            args.push(("rank", Json::num(r as f64)));
        }
        args.push(("detail", Json::str(&self.detail)));
        args
    }
}

/// Injected-fault and degradation counters (cumulative).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// page-in attempts that drew a failure
    pub pagein_failures: u64,
    /// bounded retries issued after a failed attempt
    pub pagein_retries: u64,
    /// page-ins whose whole retry budget failed (trips health)
    pub pagein_gave_up: u64,
    /// injected page-in latency spikes
    pub pagein_delays: u64,
    /// total injected backoff + delay sleep time
    pub injected_sleep_us: u64,
    /// rank-stall injections (one per stalled rank per layer execution)
    pub stalls: u64,
    pub stall_us_total: u64,
    /// expert outputs overwritten with NaN
    pub poisoned_outputs: u64,
    /// injected panics thrown
    pub panics: u64,
    /// (layer, expert) health trips
    pub tripped_experts: u64,
    /// live tokens whose top-1 expert was masked unhealthy (rerouted)
    pub degraded_tokens: u64,
    /// live tokens routed while any health mask was active on the layer
    pub routed_tokens_masked: u64,
    /// tripped experts moved to half-open probation (routing re-admitted
    /// on trial)
    pub probation_half_open: u64,
    /// half-open experts whose first clean execution re-admitted them
    pub probation_readmitted: u64,
    /// half-open experts that failed probation and re-tripped
    pub probation_retrips: u64,
    /// tripped experts restored by a rank-up recovery clause
    pub rank_up_recovered: u64,
}

// The bounded drop-oldest ledger lives in [`crate::obs`] now; re-export
// the bound so existing callers (controller, tests) keep compiling.
pub use crate::obs::EVENT_LOG_BOUND;

/// Point-in-time snapshot for `/metrics` and benches.
#[derive(Debug, Clone)]
pub struct FaultStats {
    pub plan: String,
    /// forward passes observed (layer-0 MoE executions)
    pub steps: u64,
    pub counters: FaultCounters,
    /// currently-unhealthy (layer, expert) pairs
    pub unhealthy_experts: usize,
    /// (layer, expert) pairs currently routed on half-open probation
    pub half_open_experts: usize,
    pub events: Vec<DegradationEvent>,
}

/// Injection-time state owned by the backend (wrapped in its own lock).
/// All methods are deterministic given the construction seed and the
/// call sequence.
#[derive(Debug)]
pub struct FaultState {
    plan: FaultPlan,
    retry: RetryPolicy,
    n_experts: usize,
    ep_ranks: usize,
    rng: Rng,
    /// forward passes: incremented each time layer 0's MoE stage runs
    steps: u64,
    /// `healthy[layer][expert]`
    healthy: Vec<Vec<bool>>,
    /// unhealthy count per layer (0 = mask-free fast path)
    unhealthy_per_layer: Vec<usize>,
    /// forward-pass count at which `(layer, expert)` last tripped
    /// (feeds the probation clock; `None` once fully healthy again)
    tripped_at: Vec<Vec<Option<u64>>>,
    /// `(layer, expert)` currently routed on probation: healthy in the
    /// mask, but the next execution decides re-admission vs re-trip
    half_open: Vec<Vec<bool>>,
    n_half_open: usize,
    rank_down_fired: Vec<bool>,
    rank_up_fired: Vec<bool>,
    poison_tripped: Vec<bool>,
    panic_fired: bool,
    counters: FaultCounters,
    events: EventLog<DegradationEvent>,
    /// mirror ledger pushes as `/trace` instants when tracing is on
    tracer: Option<Arc<Tracer>>,
}

/// The page-in retry schedule [`FaultState::pagein_plan`] hands back:
/// the caller performs the sleeps *outside* the fault-state lock.
#[derive(Debug, Clone, Default)]
pub struct PageinOutcome {
    /// backoff sleeps to perform between attempts, in order
    pub backoff_us: Vec<u64>,
    /// injected latency spike before the first attempt (pagein-delay)
    pub delay_us: u64,
    /// the whole retry budget failed — the expert tripped unhealthy
    pub gave_up: bool,
}

impl FaultState {
    pub fn new(plan: FaultPlan, n_layers: usize, n_experts: usize, ep_ranks: usize) -> FaultState {
        let seed = plan.pagein_fail.map(|p| p.seed).unwrap_or(0);
        let n_down = plan.rank_down.len();
        let n_up = plan.rank_up.len();
        let n_poison = plan.expert_poison.len();
        FaultState {
            plan,
            retry: RetryPolicy::default(),
            n_experts,
            ep_ranks,
            rng: Rng::new(seed ^ 0xFA_17_5EED),
            steps: 0,
            healthy: (0..n_layers).map(|_| vec![true; n_experts]).collect(),
            unhealthy_per_layer: vec![0; n_layers],
            tripped_at: (0..n_layers).map(|_| vec![None; n_experts]).collect(),
            half_open: (0..n_layers).map(|_| vec![false; n_experts]).collect(),
            n_half_open: 0,
            rank_down_fired: vec![false; n_down],
            rank_up_fired: vec![false; n_up],
            poison_tripped: vec![false; n_poison],
            panic_fired: false,
            counters: FaultCounters::default(),
            events: EventLog::default(),
            tracer: None,
        }
    }

    /// Attach (or detach) the flight recorder; subsequent ledger pushes
    /// also land as instant events on the trace timeline.
    pub fn set_tracer(&mut self, tracer: Option<Arc<Tracer>>) {
        self.tracer = tracer;
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    pub fn retry_policy(&self) -> RetryPolicy {
        self.retry
    }

    fn push_event(&mut self, ev: DegradationEvent) {
        if let Some(t) = &self.tracer {
            t.instant(ev.class.label(), EVENTS_TID, ev.trace_args());
        }
        self.events.push(ev);
    }

    /// Trip `(layer, expert)` unhealthy and log the event. Idempotent.
    /// A trip while the expert is half-open counts a probation failure
    /// and restarts its probation clock.
    pub fn trip(&mut self, layer: usize, expert: usize, class: FaultClass, detail: String) {
        if !self.healthy[layer][expert] {
            return;
        }
        if self.half_open[layer][expert] {
            self.half_open[layer][expert] = false;
            self.n_half_open -= 1;
            self.counters.probation_retrips += 1;
        }
        self.healthy[layer][expert] = false;
        self.unhealthy_per_layer[layer] += 1;
        self.tripped_at[layer][expert] = Some(self.steps);
        self.counters.tripped_experts += 1;
        self.push_event(DegradationEvent {
            step: self.steps,
            class,
            layer: Some(layer),
            expert: Some(expert),
            rank: None,
            detail,
        });
    }

    /// Advance the forward-pass clock (call when layer 0's MoE stage
    /// starts), fire any `rank-down`/`rank-up` clauses whose time has
    /// come, and move trips whose probation clock has expired to
    /// half-open.
    pub fn begin_forward_pass(&mut self) {
        self.steps += 1;
        let downs: Vec<(usize, RankDown)> = self
            .plan
            .rank_down
            .iter()
            .copied()
            .enumerate()
            .filter(|&(i, d)| !self.rank_down_fired[i] && self.steps > d.after_steps)
            .collect();
        for (i, d) in downs {
            self.rank_down_fired[i] = true;
            if d.rank >= self.ep_ranks {
                continue; // plan names a rank the backend doesn't shard to
            }
            let (e0, e1) = crate::moe::ep::rank_span(d.rank, self.n_experts, self.ep_ranks);
            for layer in 0..self.healthy.len() {
                for e in e0..e1 {
                    if self.healthy[layer][e] {
                        if self.half_open[layer][e] {
                            self.half_open[layer][e] = false;
                            self.n_half_open -= 1;
                            self.counters.probation_retrips += 1;
                        }
                        self.healthy[layer][e] = false;
                        self.unhealthy_per_layer[layer] += 1;
                        self.tripped_at[layer][e] = Some(self.steps);
                        self.counters.tripped_experts += 1;
                    }
                }
            }
            let step = self.steps;
            self.push_event(DegradationEvent {
                step,
                class: FaultClass::RankDown,
                layer: None,
                expert: None,
                rank: Some(d.rank),
                detail: format!("rank {} down: experts {e0}..{e1} masked on every layer", d.rank),
            });
        }
        let ups: Vec<(usize, RankUp)> = self
            .plan
            .rank_up
            .iter()
            .copied()
            .enumerate()
            .filter(|&(i, u)| !self.rank_up_fired[i] && self.steps > u.after_steps)
            .collect();
        for (i, u) in ups {
            self.rank_up_fired[i] = true;
            if u.rank >= self.ep_ranks {
                continue;
            }
            let (e0, e1) = crate::moe::ep::rank_span(u.rank, self.n_experts, self.ep_ranks);
            let mut restored = 0u64;
            for layer in 0..self.healthy.len() {
                for e in e0..e1 {
                    if !self.healthy[layer][e] {
                        self.healthy[layer][e] = true;
                        self.unhealthy_per_layer[layer] -= 1;
                        self.tripped_at[layer][e] = None;
                        restored += 1;
                    } else if self.half_open[layer][e] {
                        // a rank restore supersedes probation: fully healthy
                        self.half_open[layer][e] = false;
                        self.n_half_open -= 1;
                        self.tripped_at[layer][e] = None;
                        restored += 1;
                    }
                }
            }
            self.counters.rank_up_recovered += restored;
            let step = self.steps;
            self.push_event(DegradationEvent {
                step,
                class: FaultClass::RankUp,
                layer: None,
                expert: None,
                rank: Some(u.rank),
                detail: format!(
                    "rank {} up: {restored} tripped experts in {e0}..{e1} restored on every layer",
                    u.rank
                ),
            });
        }
        if let Some(p) = self.plan.probation {
            if self.unhealthy_per_layer.iter().any(|&u| u > 0) {
                for layer in 0..self.healthy.len() {
                    if self.unhealthy_per_layer[layer] == 0 {
                        continue;
                    }
                    for e in 0..self.n_experts {
                        if self.healthy[layer][e] {
                            continue;
                        }
                        let expired = match self.tripped_at[layer][e] {
                            Some(t) => self.steps.saturating_sub(t) >= p.steps,
                            None => false,
                        };
                        if !expired {
                            continue;
                        }
                        self.healthy[layer][e] = true;
                        self.unhealthy_per_layer[layer] -= 1;
                        self.half_open[layer][e] = true;
                        self.n_half_open += 1;
                        self.counters.probation_half_open += 1;
                        let step = self.steps;
                        self.push_event(DegradationEvent {
                            step,
                            class: FaultClass::Probation,
                            layer: Some(layer),
                            expert: Some(e),
                            rank: None,
                            detail: format!(
                                "layer {layer} expert {e} half-open after {} clean steps; \
                                 routing re-admitted on trial",
                                p.steps
                            ),
                        });
                    }
                }
            }
        }
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Draw the full page-in outcome for `(layer, expert)` in one call:
    /// injected delay, the bounded jittered retry schedule, and whether
    /// the retry budget was exhausted (which trips the expert). The
    /// caller sleeps outside the lock, then pages the panel in anyway —
    /// the weights are local in this simulation, so an exhausted budget
    /// degrades routing rather than wedging the step.
    pub fn pagein_plan(&mut self, layer: usize, expert: usize) -> PageinOutcome {
        let mut out = PageinOutcome::default();
        if let Some(d) = self.plan.pagein_delay {
            if self.rng.bool(d.rate) {
                out.delay_us = d.us;
                self.counters.pagein_delays += 1;
                self.counters.injected_sleep_us += d.us;
            }
        }
        if let Some(p) = self.plan.pagein_fail {
            let mut failed_all = true;
            for attempt in 0..self.retry.max_attempts {
                if !self.rng.bool(p.rate) {
                    failed_all = false;
                    break;
                }
                self.counters.pagein_failures += 1;
                if attempt + 1 < self.retry.max_attempts {
                    self.counters.pagein_retries += 1;
                    let us = backoff_us(&mut self.rng, attempt, &self.retry);
                    self.counters.injected_sleep_us += us;
                    out.backoff_us.push(us);
                }
            }
            if failed_all {
                out.gave_up = true;
                self.counters.pagein_gave_up += 1;
                self.trip(
                    layer,
                    expert,
                    FaultClass::PageinFail,
                    format!(
                        "page-in failed {} times for layer {layer} expert {expert}; \
                         masking out of routing",
                        self.retry.max_attempts
                    ),
                );
            }
        }
        out
    }

    /// Total injected stall for `rank` on one layer execution (0 = no
    /// active stall clause for this rank).
    pub fn stall_us(&mut self, rank: usize) -> u64 {
        let mut total = 0;
        for s in &self.plan.rank_stall {
            if s.rank == rank && self.steps > s.after_steps {
                total += s.us;
            }
        }
        if total > 0 {
            self.counters.stalls += 1;
            self.counters.stall_us_total += total;
        }
        total
    }

    /// Experts whose output must be poisoned on `layer` this execution.
    pub fn poison_targets(&self, layer: usize) -> Vec<usize> {
        self.plan
            .expert_poison
            .iter()
            .filter(|p| p.layer == layer)
            .map(|p| p.expert)
            .collect()
    }

    /// Record that `expert`'s output on `layer` was poisoned across
    /// `rows` routed rows; first detection trips the expert's health.
    pub fn note_poisoned(&mut self, layer: usize, expert: usize, rows: u64) {
        self.counters.poisoned_outputs += rows;
        let idx = self
            .plan
            .expert_poison
            .iter()
            .position(|p| p.layer == layer && p.expert == expert);
        if let Some(i) = idx {
            // first detection trips; a later detection only re-trips a
            // probation re-admission (the poison is persistent, so a
            // half-open expert that executes poisons again — its second
            // strike must re-open the breaker, not linger half-open)
            if !self.poison_tripped[i] || self.healthy[layer][expert] {
                self.poison_tripped[i] = true;
                self.trip(
                    layer,
                    expert,
                    FaultClass::ExpertPoison,
                    format!(
                        "non-finite output detected from layer {layer} expert {expert} \
                         ({rows} rows); masking out of routing"
                    ),
                );
            }
        }
    }

    /// One-shot injected panic check for `layer`'s MoE stage entry.
    /// Marks the panic fired *before* returning true so the engine's
    /// `catch_unwind` recovery never re-triggers it.
    pub fn should_panic(&mut self, layer: usize) -> bool {
        match self.plan.step_panic {
            Some(p) if !self.panic_fired && p.layer == layer && self.steps > p.after_steps => {
                self.panic_fired = true;
                self.counters.panics += 1;
                let step = self.steps;
                self.push_event(DegradationEvent {
                    step,
                    class: FaultClass::StepPanic,
                    layer: Some(layer),
                    expert: None,
                    rank: None,
                    detail: format!("injected panic at layer {layer} MoE entry"),
                });
                true
            }
            _ => false,
        }
    }

    /// The routing health mask for `layer`: `None` when every expert is
    /// healthy (the mask-free fast path that keeps clean runs bitwise
    /// identical) and — deliberately — when *every* expert is unhealthy:
    /// with nothing left to route to, serving degraded-but-routed beats
    /// emitting zero vectors, so total loss falls back to the unmasked
    /// decision.
    pub fn healthy_for(&self, layer: usize) -> Option<Vec<bool>> {
        let u = self.unhealthy_per_layer[layer];
        if u == 0 || u == self.n_experts {
            return None;
        }
        Some(self.healthy[layer].clone())
    }

    pub fn is_healthy(&self, layer: usize, expert: usize) -> bool {
        self.healthy[layer][expert]
    }

    /// Whether any expert is currently half-open — the backend's cheap
    /// guard before scanning an executed group for probation successes.
    pub fn has_half_open(&self) -> bool {
        self.n_half_open > 0
    }

    pub fn is_half_open(&self, layer: usize, expert: usize) -> bool {
        self.half_open[layer][expert]
    }

    /// A half-open expert executed cleanly (finite output, successful
    /// page-in): re-admit it fully. No-op unless `(layer, expert)` is
    /// half-open.
    pub fn note_probation_success(&mut self, layer: usize, expert: usize) {
        if !self.half_open[layer][expert] {
            return;
        }
        self.half_open[layer][expert] = false;
        self.n_half_open -= 1;
        self.tripped_at[layer][expert] = None;
        self.counters.probation_readmitted += 1;
        let step = self.steps;
        self.push_event(DegradationEvent {
            step,
            class: FaultClass::Probation,
            layer: Some(layer),
            expert: Some(expert),
            rank: None,
            detail: format!(
                "layer {layer} expert {expert} executed cleanly on probation; re-admitted"
            ),
        });
    }

    /// Record per-layer-step reroute accounting: `degraded` live tokens
    /// whose top-1 expert was masked, out of `routed` live tokens routed
    /// under an active mask. Logs one auditable event per layer-step
    /// that actually rerouted tokens.
    pub fn note_degraded(&mut self, layer: usize, degraded: u64, routed: u64) {
        self.counters.degraded_tokens += degraded;
        self.counters.routed_tokens_masked += routed;
        if degraded > 0 {
            let step = self.steps;
            self.push_event(DegradationEvent {
                step,
                class: FaultClass::Reroute,
                layer: Some(layer),
                expert: None,
                rank: None,
                detail: format!(
                    "{degraded}/{routed} tokens rerouted off unhealthy experts on layer {layer}"
                ),
            });
        }
    }

    pub fn stats(&self) -> FaultStats {
        FaultStats {
            plan: self.plan.label(),
            steps: self.steps,
            counters: self.counters.clone(),
            unhealthy_experts: self.unhealthy_per_layer.iter().sum(),
            half_open_experts: self.n_half_open,
            events: self.events.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let plan = FaultPlan::parse(
            "pagein-fail:rate=0.05,seed=7;rank-stall:rank=2,after_steps=50,us=20000;\
             expert-poison:layer=3,expert=11",
        )
        .unwrap();
        assert_eq!(plan.pagein_fail, Some(PageinFail { rate: 0.05, seed: 7 }));
        assert_eq!(
            plan.rank_stall,
            vec![RankStall { rank: 2, after_steps: 50, us: 20000 }]
        );
        assert_eq!(plan.expert_poison, vec![ExpertPoison { layer: 3, expert: 11 }]);
        assert!(plan.rank_down.is_empty() && plan.step_panic.is_none());
        assert!(!plan.is_empty());
    }

    #[test]
    fn label_round_trips() {
        let spec = "pagein-fail:rate=0.5,seed=3;pagein-delay:us=100,rate=0.25;\
                    rank-stall:rank=1,after_steps=2,us=300;rank-down:rank=0,after_steps=4;\
                    rank-up:rank=0,after_steps=8;expert-poison:layer=1,expert=5;\
                    step-panic:layer=0,after_steps=9;probation:steps=6";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(FaultPlan::parse(&plan.label()).unwrap(), plan);
    }

    #[test]
    fn probation_steps_zero_is_loud() {
        assert!(FaultPlan::parse("probation:steps=0").is_err());
        assert!(FaultPlan::parse("probation").is_err(), "steps is required");
    }

    #[test]
    fn empty_spec_is_the_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ;  ").unwrap().is_empty());
        assert_eq!(FaultPlan::default().label(), "");
    }

    #[test]
    fn unknown_clause_and_key_are_loud() {
        assert!(FaultPlan::parse("gpu-on-fire:rate=1").is_err());
        assert!(FaultPlan::parse("pagein-fail:rate=0.1,sed=7").is_err());
        assert!(FaultPlan::parse("pagein-fail:seed=7").is_err(), "rate is required");
        assert!(FaultPlan::parse("pagein-fail:rate=1.5").is_err(), "rate bounded");
        assert!(FaultPlan::parse("rank-stall:rank=0").is_err(), "us is required");
        assert!(FaultPlan::parse("pagein-fail:rate").is_err(), "key without value");
    }

    #[test]
    fn backoff_is_bounded_and_jittered() {
        let pol = RetryPolicy::default();
        let mut rng = Rng::new(42);
        for attempt in 0..40 {
            let us = backoff_us(&mut rng, attempt, &pol);
            assert!(us <= pol.cap_us, "attempt {attempt}: {us} > cap {}", pol.cap_us);
            assert!(us >= pol.base_us / 2, "attempt {attempt}: {us} below jitter floor");
        }
        // deep attempts saturate at the cap's jitter band, never overflow
        let us = backoff_us(&mut rng, 1000, &pol);
        assert!(us >= pol.cap_us / 2 && us <= pol.cap_us);
    }

    #[test]
    fn pagein_gave_up_trips_health_deterministically() {
        let plan = FaultPlan::parse("pagein-fail:rate=1.0,seed=9").unwrap();
        let mut a = FaultState::new(plan.clone(), 2, 8, 1);
        let mut b = FaultState::new(plan, 2, 8, 1);
        let oa = a.pagein_plan(0, 3);
        let ob = b.pagein_plan(0, 3);
        assert!(oa.gave_up && ob.gave_up);
        assert_eq!(oa.backoff_us, ob.backoff_us, "seeded runs replay identically");
        assert_eq!(oa.backoff_us.len(), a.retry_policy().max_attempts - 1);
        assert!(!a.is_healthy(0, 3));
        assert!(a.is_healthy(1, 3), "health is per-layer");
        assert_eq!(a.stats().counters.pagein_gave_up, 1);
        assert_eq!(a.stats().unhealthy_experts, 1);
        assert!(!a.stats().events.is_empty());
    }

    #[test]
    fn rate_zero_never_fails() {
        let plan = FaultPlan::parse("pagein-fail:rate=0.0,seed=1").unwrap();
        let mut s = FaultState::new(plan, 1, 4, 1);
        for e in 0..4 {
            let o = s.pagein_plan(0, e);
            assert!(!o.gave_up && o.backoff_us.is_empty());
        }
        assert_eq!(s.stats().counters.pagein_failures, 0);
    }

    #[test]
    fn rank_down_masks_the_shard_after_its_step() {
        let plan = FaultPlan::parse("rank-down:rank=1,after_steps=2").unwrap();
        let mut s = FaultState::new(plan, 2, 8, 2); // rank 1 owns experts 4..8
        s.begin_forward_pass();
        s.begin_forward_pass();
        assert!(s.healthy_for(0).is_none(), "not yet fired");
        s.begin_forward_pass();
        let h = s.healthy_for(0).expect("mask active");
        assert_eq!(h, vec![true, true, true, true, false, false, false, false]);
        assert!(s.healthy_for(1).is_some(), "all layers masked");
        assert_eq!(s.stats().counters.tripped_experts, 8);
    }

    #[test]
    fn total_loss_falls_back_to_unmasked() {
        let plan = FaultPlan::parse("rank-down:rank=0").unwrap();
        let mut s = FaultState::new(plan, 1, 4, 1); // rank 0 owns everything
        s.begin_forward_pass();
        assert_eq!(s.stats().unhealthy_experts, 4);
        assert!(s.healthy_for(0).is_none(), "all-down layer routes unmasked");
    }

    #[test]
    fn stall_activates_after_steps() {
        let plan = FaultPlan::parse("rank-stall:rank=0,after_steps=1,us=500").unwrap();
        let mut s = FaultState::new(plan, 1, 4, 2);
        s.begin_forward_pass();
        assert_eq!(s.stall_us(0), 0, "inactive before after_steps");
        s.begin_forward_pass();
        assert_eq!(s.stall_us(0), 500);
        assert_eq!(s.stall_us(1), 0, "other ranks unaffected");
        assert_eq!(s.stats().counters.stall_us_total, 500);
    }

    #[test]
    fn poison_trips_once_and_counts_rows() {
        let plan = FaultPlan::parse("expert-poison:layer=0,expert=2").unwrap();
        let mut s = FaultState::new(plan, 2, 4, 1);
        assert_eq!(s.poison_targets(0), vec![2]);
        assert!(s.poison_targets(1).is_empty());
        s.note_poisoned(0, 2, 5);
        s.note_poisoned(0, 2, 3);
        assert_eq!(s.stats().counters.poisoned_outputs, 8);
        assert_eq!(s.stats().counters.tripped_experts, 1, "trip is idempotent");
        assert!(!s.is_healthy(0, 2));
    }

    #[test]
    fn step_panic_fires_exactly_once() {
        let plan = FaultPlan::parse("step-panic:layer=1,after_steps=1").unwrap();
        let mut s = FaultState::new(plan, 2, 4, 1);
        s.begin_forward_pass();
        assert!(!s.should_panic(1), "before after_steps");
        s.begin_forward_pass();
        assert!(!s.should_panic(0), "wrong layer");
        assert!(s.should_panic(1));
        assert!(!s.should_panic(1), "one-shot");
        assert_eq!(s.stats().counters.panics, 1);
    }

    #[test]
    fn rank_up_restores_the_shard_after_its_step() {
        let plan = FaultPlan::parse("rank-down:rank=1;rank-up:rank=1,after_steps=3").unwrap();
        let mut s = FaultState::new(plan, 2, 8, 2); // rank 1 owns experts 4..8
        s.begin_forward_pass();
        assert!(s.healthy_for(0).is_some(), "rank 1 down");
        s.begin_forward_pass();
        s.begin_forward_pass();
        assert!(s.healthy_for(0).is_some(), "rank-up not yet fired");
        s.begin_forward_pass();
        assert!(s.healthy_for(0).is_none(), "rank 1 restored on every layer");
        assert!(s.healthy_for(1).is_none());
        let st = s.stats();
        assert_eq!(st.counters.rank_up_recovered, 8);
        assert_eq!(st.unhealthy_experts, 0);
        assert!(st
            .events
            .iter()
            .any(|e| e.class == FaultClass::RankUp && e.rank == Some(1)));
    }

    #[test]
    fn probation_half_opens_then_readmits_on_success() {
        let plan = FaultPlan::parse("probation:steps=2").unwrap();
        let mut s = FaultState::new(plan, 1, 4, 1);
        s.begin_forward_pass(); // step 1
        s.trip(0, 2, FaultClass::PageinFail, "boom".into());
        assert!(!s.is_healthy(0, 2));
        s.begin_forward_pass(); // step 2: 1 step since trip — not yet
        assert!(!s.is_healthy(0, 2) && !s.has_half_open());
        s.begin_forward_pass(); // step 3: clock expired -> half-open
        assert!(s.is_healthy(0, 2), "half-open experts route again");
        assert!(s.is_half_open(0, 2) && s.has_half_open());
        assert_eq!(s.stats().half_open_experts, 1);
        assert_eq!(s.stats().counters.probation_half_open, 1);
        s.note_probation_success(0, 2);
        assert!(!s.has_half_open(), "clean execution re-admits fully");
        assert_eq!(s.stats().counters.probation_readmitted, 1);
        // fully healthy: later forward passes never re-open probation
        s.begin_forward_pass();
        assert!(!s.has_half_open());
        // success on a non-half-open expert is a no-op
        s.note_probation_success(0, 1);
        assert_eq!(s.stats().counters.probation_readmitted, 1);
    }

    #[test]
    fn probation_retrip_restarts_the_clock() {
        let plan = FaultPlan::parse("probation:steps=2").unwrap();
        let mut s = FaultState::new(plan, 1, 4, 1);
        s.begin_forward_pass(); // step 1
        s.trip(0, 0, FaultClass::ExpertPoison, "nan".into());
        s.begin_forward_pass(); // 2
        s.begin_forward_pass(); // 3 -> half-open
        assert!(s.is_half_open(0, 0));
        // probation failed: the expert misbehaves again while half-open
        s.trip(0, 0, FaultClass::ExpertPoison, "nan again".into());
        assert!(!s.is_healthy(0, 0) && !s.has_half_open());
        assert_eq!(s.stats().counters.probation_retrips, 1);
        s.begin_forward_pass(); // 4: 1 step since re-trip — stays tripped
        assert!(!s.is_healthy(0, 0), "re-trip restarted the clock");
        s.begin_forward_pass(); // 5 -> half-open again
        assert!(s.is_half_open(0, 0));
        assert_eq!(s.stats().counters.probation_half_open, 2);
    }

    #[test]
    fn no_probation_clause_keeps_trips_permanent() {
        let plan = FaultPlan::parse("pagein-fail:rate=1.0,seed=3").unwrap();
        let mut s = FaultState::new(plan, 1, 4, 1);
        s.begin_forward_pass();
        s.trip(0, 1, FaultClass::PageinFail, "boom".into());
        for _ in 0..50 {
            s.begin_forward_pass();
        }
        assert!(!s.is_healthy(0, 1), "pessimistic default unchanged");
        assert!(!s.has_half_open());
    }

    #[test]
    fn event_log_is_bounded() {
        let plan = FaultPlan::parse("pagein-fail:rate=1.0,seed=1").unwrap();
        let mut s = FaultState::new(plan, 1, 4, 1);
        for i in 0..(EVENT_LOG_BOUND + 50) {
            s.note_degraded(0, 1 + (i as u64 % 3), 4);
        }
        let st = s.stats();
        assert_eq!(st.events.len(), EVENT_LOG_BOUND);
        assert_eq!(st.events.last().unwrap().step, 0);
        assert!(st.counters.degraded_tokens > EVENT_LOG_BOUND as u64);
    }
}
