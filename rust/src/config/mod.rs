//! Model / artifact configuration, parsed from `artifacts/<cfg>/manifest.json`
//! (written by `python/compile/aot.py`; single source of truth is
//! `python/compile/configs.py`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Mirror of `python/compile/configs.py::ModelConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_expert: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub s_max: usize,
    pub n_domains: usize,
    pub batch_buckets: Vec<usize>,
    pub t_buckets: Vec<usize>,
    pub prefill_chunk: usize,
}

impl ModelConfig {
    pub fn q_dim(&self) -> usize {
        self.n_q_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Smallest batch bucket that fits `b` live rows (the CUDA-graph
    /// padding analogy, paper §6).
    pub fn bucket_for(&self, b: usize) -> Result<usize> {
        self.batch_buckets
            .iter()
            .copied()
            .filter(|&x| x >= b)
            .min()
            .ok_or_else(|| {
                Error::Config(format!(
                    "batch {b} exceeds largest bucket {:?}",
                    self.batch_buckets.iter().max()
                ))
            })
    }

    /// Smallest T bucket that fits `t` active experts (t=0 uses the
    /// smallest bucket; the combine matrix is all-zero there).
    pub fn t_bucket_for(&self, t: usize) -> Result<usize> {
        self.t_buckets
            .iter()
            .copied()
            .filter(|&x| x >= t.max(1))
            .min()
            .ok_or_else(|| Error::Config(format!("T={t} exceeds N={}", self.n_experts)))
    }

    fn from_json(v: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: v.get("name")?.as_str()?.to_string(),
            n_layers: v.get("n_layers")?.as_usize()?,
            d_model: v.get("d_model")?.as_usize()?,
            n_experts: v.get("n_experts")?.as_usize()?,
            top_k: v.get("top_k")?.as_usize()?,
            d_expert: v.get("d_expert")?.as_usize()?,
            n_q_heads: v.get("n_q_heads")?.as_usize()?,
            n_kv_heads: v.get("n_kv_heads")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            s_max: v.get("s_max")?.as_usize()?,
            n_domains: v.get("n_domains")?.as_usize()?,
            batch_buckets: v.get("batch_buckets")?.usize_list()?,
            t_buckets: v.get("t_buckets")?.usize_list()?,
            prefill_chunk: v.get("prefill_chunk")?.as_usize()?,
        })
    }
}

/// One exported HLO stage.
#[derive(Debug, Clone, PartialEq)]
pub struct StageInfo {
    pub file: String,
    pub outputs: usize,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub stages: BTreeMap<String, StageInfo>,
    pub weights_file: String,
    pub vocab_file: String,
}

impl Manifest {
    pub fn load(artifact_root: &Path, cfg_name: &str) -> Result<Manifest> {
        let dir = artifact_root.join(cfg_name);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "{path:?}: {e} — run `make artifacts` (or artifacts-base) first"
            ))
        })?;
        let v = Json::parse(&text)?;
        let config = ModelConfig::from_json(v.get("config")?)?;
        let mut stages = BTreeMap::new();
        for (name, s) in v.get("stages")?.as_obj()? {
            stages.insert(
                name.clone(),
                StageInfo {
                    file: s.get("file")?.as_str()?.to_string(),
                    outputs: s.get("outputs")?.as_usize()?,
                },
            );
        }
        Ok(Manifest {
            dir,
            config,
            stages,
            weights_file: v.get("weights")?.as_str()?.to_string(),
            vocab_file: v.get("vocab")?.as_str()?.to_string(),
        })
    }

    pub fn stage(&self, name: &str) -> Result<&StageInfo> {
        self.stages
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("stage {name:?} not in manifest")))
    }

    pub fn stage_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.stage(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            n_layers: 2,
            d_model: 64,
            n_experts: 8,
            top_k: 2,
            d_expert: 32,
            n_q_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            vocab: 512,
            s_max: 128,
            n_domains: 4,
            batch_buckets: vec![1, 2, 4, 8],
            t_buckets: vec![2, 4, 6, 8],
            prefill_chunk: 16,
        }
    }

    #[test]
    fn bucket_selection() {
        let c = cfg();
        assert_eq!(c.bucket_for(1).unwrap(), 1);
        assert_eq!(c.bucket_for(3).unwrap(), 4);
        assert_eq!(c.bucket_for(8).unwrap(), 8);
        assert!(c.bucket_for(9).is_err());
    }

    #[test]
    fn t_bucket_selection() {
        let c = cfg();
        assert_eq!(c.t_bucket_for(0).unwrap(), 2);
        assert_eq!(c.t_bucket_for(2).unwrap(), 2);
        assert_eq!(c.t_bucket_for(5).unwrap(), 6);
        assert_eq!(c.t_bucket_for(8).unwrap(), 8);
        assert!(c.t_bucket_for(9).is_err());
    }

    #[test]
    fn parses_manifest_json() {
        let j = r#"{
          "config": {"name":"t","n_layers":2,"d_model":64,"n_experts":8,
            "top_k":2,"d_expert":32,"n_q_heads":4,"n_kv_heads":2,
            "head_dim":16,"vocab":512,"s_max":128,"n_domains":4,
            "batch_buckets":[1,2],"t_buckets":[2,4],"prefill_chunk":16},
          "weights": "weights.npz", "vocab": "vocab.json",
          "stages": {"embed_b1": {"file": "embed_b1.hlo.txt", "outputs": 1}}
        }"#;
        let dir = std::env::temp_dir().join("oea_manifest_test");
        std::fs::create_dir_all(dir.join("t")).unwrap();
        std::fs::write(dir.join("t/manifest.json"), j).unwrap();
        let m = Manifest::load(&dir, "t").unwrap();
        assert_eq!(m.config.n_experts, 8);
        assert_eq!(m.stage("embed_b1").unwrap().outputs, 1);
        assert!(m.stage("nope").is_err());
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let err = Manifest::load(Path::new("/nonexistent"), "x").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
