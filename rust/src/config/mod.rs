//! Model / artifact configuration, parsed from `artifacts/<cfg>/manifest.json`
//! (written by `python/compile/aot.py`; single source of truth is
//! `python/compile/configs.py`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Mirror of `python/compile/configs.py::ModelConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_expert: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub s_max: usize,
    pub n_domains: usize,
    pub batch_buckets: Vec<usize>,
    pub t_buckets: Vec<usize>,
    pub prefill_chunk: usize,
    pub rope_theta: f32,
    pub rms_eps: f32,
}

/// Default active-expert buckets: N/8 steps (mirrors configs.py).
fn default_t_buckets(n_experts: usize) -> Vec<usize> {
    let step = (n_experts / 8).max(1);
    (1..=n_experts / step).map(|i| i * step).collect()
}

impl ModelConfig {
    /// Built-in preset mirroring `python/compile/configs.py`, so the CPU
    /// backend runs without any Python-generated manifest.
    pub fn preset(name: &str) -> Result<ModelConfig> {
        let c = match name {
            "tiny" => ModelConfig {
                name: "tiny".into(),
                n_layers: 2,
                d_model: 64,
                n_experts: 8,
                top_k: 2,
                d_expert: 32,
                n_q_heads: 4,
                n_kv_heads: 2,
                head_dim: 16,
                vocab: 512,
                s_max: 128,
                n_domains: 4,
                batch_buckets: vec![1, 2, 4, 8],
                t_buckets: default_t_buckets(8),
                prefill_chunk: 16,
                rope_theta: 10000.0,
                rms_eps: 1e-6,
            },
            "small" => ModelConfig {
                name: "small".into(),
                n_layers: 8,
                d_model: 256,
                n_experts: 32,
                top_k: 8,
                d_expert: 128,
                n_q_heads: 8,
                n_kv_heads: 2,
                head_dim: 32,
                vocab: 1024,
                s_max: 256,
                n_domains: 4,
                batch_buckets: vec![1, 2, 4, 8, 16, 32],
                t_buckets: default_t_buckets(32),
                prefill_chunk: 64,
                rope_theta: 10000.0,
                rms_eps: 1e-6,
            },
            "base" => ModelConfig {
                name: "base".into(),
                n_layers: 12,
                d_model: 384,
                n_experts: 64,
                top_k: 8,
                d_expert: 192,
                n_q_heads: 8,
                n_kv_heads: 2,
                head_dim: 48,
                vocab: 1024,
                s_max: 256,
                n_domains: 4,
                batch_buckets: vec![1, 8, 16, 32],
                t_buckets: default_t_buckets(64),
                prefill_chunk: 64,
                rope_theta: 10000.0,
                rms_eps: 1e-6,
            },
            // CI bench-smoke shape: structured like `small` (enough experts
            // for k0 sweeps) but cheap enough for a few seconds per bench.
            "smoke" => ModelConfig {
                name: "smoke".into(),
                n_layers: 2,
                d_model: 64,
                n_experts: 16,
                top_k: 4,
                d_expert: 32,
                n_q_heads: 4,
                n_kv_heads: 2,
                head_dim: 16,
                vocab: 512,
                s_max: 64,
                n_domains: 4,
                batch_buckets: vec![1, 2, 4, 8, 16],
                t_buckets: default_t_buckets(16),
                prefill_chunk: 16,
                rope_theta: 10000.0,
                rms_eps: 1e-6,
            },
            other => {
                return Err(Error::Config(format!(
                    "unknown config preset {other:?} (tiny|small|base|smoke)"
                )))
            }
        };
        debug_assert_eq!(c.d_model, c.n_q_heads * c.head_dim);
        Ok(c)
    }

    pub fn q_dim(&self) -> usize {
        self.n_q_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Smallest batch bucket that fits `b` live rows (the CUDA-graph
    /// padding analogy, paper §6).
    pub fn bucket_for(&self, b: usize) -> Result<usize> {
        self.batch_buckets
            .iter()
            .copied()
            .filter(|&x| x >= b)
            .min()
            .ok_or_else(|| {
                Error::Config(format!(
                    "batch {b} exceeds largest bucket {:?}",
                    self.batch_buckets.iter().max()
                ))
            })
    }

    /// Smallest T bucket that fits `t` active experts (t=0 uses the
    /// smallest bucket; the combine matrix is all-zero there).
    pub fn t_bucket_for(&self, t: usize) -> Result<usize> {
        self.t_buckets
            .iter()
            .copied()
            .filter(|&x| x >= t.max(1))
            .min()
            .ok_or_else(|| Error::Config(format!("T={t} exceeds N={}", self.n_experts)))
    }

    fn from_json(v: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: v.get("name")?.as_str()?.to_string(),
            n_layers: v.get("n_layers")?.as_usize()?,
            d_model: v.get("d_model")?.as_usize()?,
            n_experts: v.get("n_experts")?.as_usize()?,
            top_k: v.get("top_k")?.as_usize()?,
            d_expert: v.get("d_expert")?.as_usize()?,
            n_q_heads: v.get("n_q_heads")?.as_usize()?,
            n_kv_heads: v.get("n_kv_heads")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            s_max: v.get("s_max")?.as_usize()?,
            n_domains: v.get("n_domains")?.as_usize()?,
            batch_buckets: v.get("batch_buckets")?.usize_list()?,
            t_buckets: v.get("t_buckets")?.usize_list()?,
            prefill_chunk: v.get("prefill_chunk")?.as_usize()?,
            rope_theta: match v.get_opt("rope_theta") {
                Some(x) => x.as_f64()? as f32,
                None => 10000.0,
            },
            rms_eps: match v.get_opt("rms_eps") {
                Some(x) => x.as_f64()? as f32,
                None => 1e-6,
            },
        })
    }
}

/// One exported HLO stage.
#[derive(Debug, Clone, PartialEq)]
pub struct StageInfo {
    pub file: String,
    pub outputs: usize,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub stages: BTreeMap<String, StageInfo>,
    pub weights_file: String,
    pub vocab_file: String,
}

impl Manifest {
    pub fn load(artifact_root: &Path, cfg_name: &str) -> Result<Manifest> {
        let dir = artifact_root.join(cfg_name);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "{path:?}: {e} — run `make artifacts` (or artifacts-base) first"
            ))
        })?;
        let v = Json::parse(&text)?;
        let config = ModelConfig::from_json(v.get("config")?)?;
        let mut stages = BTreeMap::new();
        for (name, s) in v.get("stages")?.as_obj()? {
            stages.insert(
                name.clone(),
                StageInfo {
                    file: s.get("file")?.as_str()?.to_string(),
                    outputs: s.get("outputs")?.as_usize()?,
                },
            );
        }
        Ok(Manifest {
            dir,
            config,
            stages,
            weights_file: v.get("weights")?.as_str()?.to_string(),
            vocab_file: v.get("vocab")?.as_str()?.to_string(),
        })
    }

    pub fn stage(&self, name: &str) -> Result<&StageInfo> {
        self.stages
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("stage {name:?} not in manifest")))
    }

    pub fn stage_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.stage(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            n_layers: 2,
            d_model: 64,
            n_experts: 8,
            top_k: 2,
            d_expert: 32,
            n_q_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            vocab: 512,
            s_max: 128,
            n_domains: 4,
            batch_buckets: vec![1, 2, 4, 8],
            t_buckets: vec![2, 4, 6, 8],
            prefill_chunk: 16,
            rope_theta: 10000.0,
            rms_eps: 1e-6,
        }
    }

    #[test]
    fn bucket_selection() {
        let c = cfg();
        assert_eq!(c.bucket_for(1).unwrap(), 1);
        assert_eq!(c.bucket_for(3).unwrap(), 4);
        assert_eq!(c.bucket_for(8).unwrap(), 8);
        assert!(c.bucket_for(9).is_err());
    }

    #[test]
    fn t_bucket_selection() {
        let c = cfg();
        assert_eq!(c.t_bucket_for(0).unwrap(), 2);
        assert_eq!(c.t_bucket_for(2).unwrap(), 2);
        assert_eq!(c.t_bucket_for(5).unwrap(), 6);
        assert_eq!(c.t_bucket_for(8).unwrap(), 8);
        assert!(c.t_bucket_for(9).is_err());
    }

    #[test]
    fn parses_manifest_json() {
        let j = r#"{
          "config": {"name":"t","n_layers":2,"d_model":64,"n_experts":8,
            "top_k":2,"d_expert":32,"n_q_heads":4,"n_kv_heads":2,
            "head_dim":16,"vocab":512,"s_max":128,"n_domains":4,
            "batch_buckets":[1,2],"t_buckets":[2,4],"prefill_chunk":16},
          "weights": "weights.npz", "vocab": "vocab.json",
          "stages": {"embed_b1": {"file": "embed_b1.hlo.txt", "outputs": 1}}
        }"#;
        let dir = std::env::temp_dir().join("oea_manifest_test");
        std::fs::create_dir_all(dir.join("t")).unwrap();
        std::fs::write(dir.join("t/manifest.json"), j).unwrap();
        let m = Manifest::load(&dir, "t").unwrap();
        assert_eq!(m.config.n_experts, 8);
        assert_eq!(m.stage("embed_b1").unwrap().outputs, 1);
        assert!(m.stage("nope").is_err());
    }

    #[test]
    fn presets_mirror_configs_py() {
        let t = ModelConfig::preset("tiny").unwrap();
        assert_eq!(t.n_experts, 8);
        assert_eq!(t.top_k, 2);
        assert_eq!(t.t_buckets, vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let s = ModelConfig::preset("small").unwrap();
        assert_eq!(s.d_model, s.n_q_heads * s.head_dim);
        assert_eq!(s.t_buckets, vec![4, 8, 12, 16, 20, 24, 28, 32]);
        let b = ModelConfig::preset("base").unwrap();
        assert_eq!(b.t_buckets.len(), 8);
        assert!(ModelConfig::preset("nope").is_err());
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let err = Manifest::load(Path::new("/nonexistent"), "x").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
