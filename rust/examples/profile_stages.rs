//! Per-stage profiling tool (perf work, DESIGN.md §8 / EXPERIMENTS.md
//! §Perf): times the decode step on the CPU backend at B=16 and breaks out
//! the MoE stage and routing decision. Run after any kernel change.
//!
//!     cargo run --release --example profile_stages
//!     OEA_BENCH_CONFIG=small cargo run --release --example profile_stages

use std::time::Instant;

use oea_serve::backend::cpu::CpuBackend;
use oea_serve::config::ModelConfig;
use oea_serve::model::ModelRunner;

fn main() {
    let c = ModelConfig::preset(
        &std::env::var("OEA_BENCH_CONFIG").unwrap_or_else(|_| "smoke".into()),
    )
    .unwrap();
    let b = 16usize;
    let runner = ModelRunner::new(CpuBackend::synthetic(c.clone(), 0));
    let mut batch = runner.new_batch(b).unwrap();
    let tokens: Vec<i32> = (0..b as i32).map(|i| 3 + i * 17).collect();
    let live = vec![true; b];
    for step in 0..6 {
        let pos = vec![step as i32; b];
        let t0 = Instant::now();
        let out = runner
            .decode_step(
                &mut batch,
                &tokens,
                &pos,
                &live,
                oea_serve::moe::policy::Policy::Vanilla { k: c.top_k },
                true,
            )
            .unwrap();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let avg_t: f64 =
            out.layers.iter().map(|l| l.t as f64).sum::<f64>() / out.layers.len() as f64;
        let moe_ms: f64 = out.layers.iter().map(|l| l.moe_us).sum::<f64>() / 1e3;
        let route_us: f64 = out.layers.iter().map(|l| l.route_us).sum::<f64>();
        println!(
            "step {step}: {ms:.1}ms total | moe(sum) {moe_ms:.1}ms | \
             route(sum) {route_us:.0}us | avg_t {avg_t:.1}"
        );
    }
}
