//! END-TO-END serving driver (DESIGN.md E-e2e): starts the real HTTP
//! server on the hermetic CPU backend, drives it with concurrent client
//! requests over TCP, and reports latency/throughput plus the MoE
//! telemetry — once under vanilla routing and once under OEA.
//!
//!     cargo run --release --example serve_e2e
//!     OEA_E2E_CONFIG=small cargo run --release --example serve_e2e

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use oea_serve::backend::cpu::CpuBackend;
use oea_serve::config::ModelConfig;
use oea_serve::coordinator::{Engine, EngineConfig};
use oea_serve::latency::H100Presets;
use oea_serve::model::ModelRunner;
use oea_serve::moe::policy::PolicySpec;
use oea_serve::server;
use oea_serve::util::bpe::Tokenizer;
use oea_serve::util::json::Json;
use oea_serve::util::stats;

const N_REQUESTS: usize = 12;
const MAX_TOKENS: usize = 24;

fn http_post(addr: &str, path: &str, body: &str) -> Result<String, std::io::Error> {
    let mut s = TcpStream::connect(addr)?;
    s.set_read_timeout(Some(Duration::from_secs(600)))?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes())?;
    let mut out = String::new();
    s.read_to_string(&mut out)?;
    Ok(out
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or(out))
}

fn cfg_name() -> String {
    std::env::var("OEA_E2E_CONFIG").unwrap_or_else(|_| "smoke".into())
}

fn run_one(policy_spec: &str, port: u16) -> (f64, f64, Vec<f64>) {
    let addr = format!("127.0.0.1:{port}");
    let spec = policy_spec.to_string();
    let server_thread = std::thread::spawn(move || {
        let tok = Tokenizer::byte_level();
        let cfg = ModelConfig::preset(&cfg_name()).unwrap();
        let policy = PolicySpec::parse(&spec)
            .and_then(|s| s.build(cfg.top_k, cfg.n_experts))
            .unwrap();
        let cost = H100Presets::for_config(&cfg.name);
        server::serve(
            move || {
                // the engine is built on the engine thread (backends may
                // own non-Send handles; the CPU backend just rides along)
                Engine::new(
                    ModelRunner::new(CpuBackend::synthetic(cfg, 0)),
                    EngineConfig {
                        max_running: 8,
                        max_queue: 64,
                        ..EngineConfig::new(policy, cost)
                    },
                )
            },
            tok,
            &format!("127.0.0.1:{port}"),
            server::ServeOptions::default(), // stopped via POST /shutdown
        )
        .unwrap();
    });

    // wait for the listener
    std::thread::sleep(Duration::from_millis(300));
    while TcpStream::connect(&addr).is_err() {
        std::thread::sleep(Duration::from_millis(100));
    }

    let prompts: Vec<String> = (0..N_REQUESTS)
        .map(|i| {
            format!(
                "request {i}: the quiet river carried lantern number {} downstream",
                i * 7 % 13
            )
        })
        .collect();

    // all clients at once: the engine batches up to max_running=8 and
    // queues the rest (continuous batching under real concurrency)
    let t0 = Instant::now();
    let mut lat_ms = Vec::new();
    let mut total_tokens = 0usize;
    for wave in prompts.chunks(N_REQUESTS) {
        let handles: Vec<_> = wave
            .iter()
            .cloned()
            .map(|p| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let body = Json::obj(vec![
                        ("prompt", Json::str(&p)),
                        ("max_tokens", Json::num(MAX_TOKENS as f64)),
                        ("temperature", Json::num(0.6)),
                        ("top_p", Json::num(0.95)),
                    ])
                    .write();
                    let t = Instant::now();
                    let resp = http_post(&addr, "/generate", &body).unwrap();
                    (t.elapsed().as_secs_f64() * 1e3, resp)
                })
            })
            .collect();
        for h in handles {
            let (ms, resp) = h.join().unwrap();
            let v = Json::parse(&resp).expect("json response");
            total_tokens += v.get("n_tokens").unwrap().as_usize().unwrap();
            lat_ms.push(ms);
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    // fetch metrics, then drain the server via POST /shutdown
    let metrics_raw = {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap()
    };
    let m = Json::parse(&metrics_raw).unwrap();
    let avg_t = m.get("avg_active_experts").unwrap().as_f64().unwrap();
    let sim_us = m.get("avg_moe_us_simulated").unwrap().as_f64().unwrap();

    // graceful drain: the server stops accepting and exits once idle
    let _ = http_post(&addr, "/shutdown", "");
    server_thread.join().unwrap();

    println!(
        "policy={policy_spec:<12} {} requests, {} tokens in {:.1}s -> {:.1} tok/s; \
         client p50 latency {:.0} ms; avg T {:.1}; simulated H100 MoE {:.1} us/layer",
        N_REQUESTS,
        total_tokens,
        wall_s,
        total_tokens as f64 / wall_s,
        stats::percentile(&lat_ms, 50.0),
        avg_t,
        sim_us,
    );
    (avg_t, sim_us, lat_ms)
}

fn main() {
    println!(
        "=== end-to-end serving: {} model (cpu backend), HTTP API, {} requests ===",
        cfg_name(),
        N_REQUESTS
    );
    let (t_v, us_v, _) = run_one("vanilla", 18080);
    let (t_o, us_o, _) = run_one("oea:k0=3", 18081);
    println!(
        "\nOEA vs vanilla: active experts {:.1} -> {:.1} ({:.0}%), \
         simulated H100 MoE latency {:.1} -> {:.1} us ({:.0}% reduction; \
         paper reports 39% at k0=3 on Qwen3-30B)",
        t_v,
        t_o,
        100.0 * (1.0 - t_o / t_v),
        us_v,
        us_o,
        100.0 * (1.0 - us_o / us_v),
    );
}
