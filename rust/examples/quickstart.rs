//! Quickstart: build the hermetic CPU model, serve a handful of requests
//! under vanilla routing and under OEA, and compare activated experts /
//! latency. No artifacts, Python, or XLA required.
//!
//!     cargo run --release --example quickstart

use oea_serve::backend::cpu::CpuBackend;
use oea_serve::config::ModelConfig;
use oea_serve::coordinator::{Engine, EngineConfig, GenRequest};
use oea_serve::latency::H100Presets;
use oea_serve::model::ModelRunner;
use oea_serve::moe::policy::Policy;
use oea_serve::util::bpe::Tokenizer;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = ModelConfig::preset("smoke")?;
    let k = cfg.top_k;
    let tok = Tokenizer::byte_level();

    let prompts = [
        "The quiet river carried the ancient lantern",
        "let total: int = buffer % 42;",
        "Q: what is the boiling point of the harbour? A:",
        "integral of sin(t) cos(t) dt from 0 to 3",
    ];

    for policy in [
        Policy::Vanilla { k },
        Policy::OeaSimplified { k0: 2, k },
    ] {
        // same seed -> identical weights in both arms
        let runner = ModelRunner::new(CpuBackend::synthetic(cfg.clone(), 0));
        let mut engine = Engine::new(
            runner,
            EngineConfig {
                max_running: 4,
                max_queue: usize::MAX, // offline: the whole workload queues
                ..EngineConfig::new(policy, H100Presets::for_config(&cfg.name))
            },
        )?;
        println!("=== policy: {} ===", policy.label());
        for (i, p) in prompts.iter().enumerate() {
            let ids: Vec<i32> = tok.encode(p).iter().map(|&t| t as i32).collect();
            engine.submit(GenRequest::greedy(i as u64, ids, 16))?;
        }
        let done = engine.run_to_completion()?;
        for f in &done {
            let text = tok.decode(&f.tokens.iter().map(|&t| t as u32).collect::<Vec<_>>());
            println!("  [{}] {}…{}", f.id, prompts[f.id as usize], text.trim_end());
        }
        println!(
            "  avg active experts T = {:.1}, simulated H100 MoE latency = {:.1} us, \
             measured CPU MoE latency = {:.1} us\n",
            engine.moe.avg_t(),
            engine.moe.avg_latency_us(true),
            engine.moe.avg_latency_us(false),
        );
    }
    println!(
        "OEA activates fewer unique experts per step at the same per-token\n\
         budget — the mechanism behind the paper's 39% decode speedup."
    );
    Ok(())
}
