//! Reproduction of the paper's §6 padding anecdote: under CUDA-graph-style
//! batch buckets, a batch of 7 live requests padded to bucket 8 can cost
//! MORE than 8 live requests, because the padding row routes freely to
//! "out-of-distribution" experts. The fix — zeroing padding tokens' expert
//! choices — makes the 7-live batch strictly cheaper.
//!
//!     cargo run --release --example padding_anecdote

use oea_serve::backend::cpu::CpuBackend;
use oea_serve::config::ModelConfig;
use oea_serve::eval;
use oea_serve::latency::H100Presets;
use oea_serve::model::ModelRunner;
use oea_serve::moe::policy::{route, Policy, RoutingInput};
use oea_serve::moe::ScoreMatrix;
use oea_serve::util::bench::Table;
use oea_serve::util::rng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let c = ModelConfig::preset(
        &std::env::var("OEA_BENCH_CONFIG").unwrap_or_else(|_| "smoke".into()),
    )?;
    let runner = ModelRunner::new(CpuBackend::synthetic(c.clone(), 0));
    let mut rng = Rng::new(0);
    let cost = H100Presets::for_config(&c.name);
    let positions = 12;

    // 8 domain-pure sequences; variants use the first `live` of them
    let seqs = eval::synthetic_sequences(&c, &mut rng, 8, positions, false);

    let mut table = Table::new(
        "Paper §6 padding anecdote (bucket = 8, vanilla routing)",
        &["batch", "padding mask", "avg T", "sim us/layer"],
    );

    for (live_n, mask) in [(8usize, true), (7, true), (7, false)] {
        let mut batch = runner.new_batch(8)?;
        let mut toks = vec![0i32; 8];
        let mut pos = vec![0i32; 8];
        let mut live = vec![false; 8];
        for item in live.iter_mut().take(live_n) {
            *item = true;
        }
        let mut sum_t = 0.0;
        let mut n = 0usize;
        for t in 0..positions {
            for i in 0..8 {
                // padding rows still receive a (pad) token id, like
                // SGLang's captured-graph padding does
                toks[i] = if live[i] { seqs[i][t] } else { 3 };
                pos[i] = t as i32;
            }
            let out = runner.decode_step(
                &mut batch, &toks, &pos, &live,
                Policy::Vanilla { k: c.top_k }, mask,
            )?;
            for ls in &out.layers {
                sum_t += ls.t as f64;
                n += 1;
            }
        }
        let avg_t = sum_t / n as f64;
        table.row(vec![
            format!("{live_n} live"),
            if mask { "on".into() } else { "off (anecdote)".into() },
            format!("{avg_t:.2}"),
            format!("{:.1}", cost.layer_us(avg_t.round() as usize, live_n * c.top_k, 0)),
        ]);
    }
    table.print();
    println!(
        "\nAs in the paper: without the mask the padded batch of 7 activates\n\
         extra out-of-distribution experts via its pad row; with the mask it\n\
         is strictly cheaper than the full batch of 8.\n"
    );

    // routing-layer visualization of the same effect on one step
    let mut scores = vec![0.0f32; 8 * c.n_experts];
    let mut r2 = Rng::new(7);
    for i in 0..8 {
        let row = &mut scores[i * c.n_experts..(i + 1) * c.n_experts];
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (2.0 * r2.gaussian()).exp() as f32;
            sum += *x;
        }
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
    let sm = ScoreMatrix::new(8, c.n_experts, scores);
    let mut live = vec![true; 8];
    live[7] = false;
    for mask in [true, false] {
        let d = route(
            Policy::Vanilla { k: c.top_k },
            &RoutingInput::new(&sm, &live, mask),
        );
        println!("single-step routing with 7 live rows, mask={mask}: T = {}", d.t());
    }
    Ok(())
}
