//! Cross-entropy hyperparameter sweep (paper §4.1, condensed): pruned vs
//! OEA arms at one batch size, printed as the Pareto trade-off between
//! quality delta and average activated experts. The full figure
//! reproductions live in `cargo bench --bench fig_ce_pareto` and
//! `--bench fig_ablations`; this example is the quick interactive version.
//!
//!     cargo run --release --example ce_sweep [-- <batch> <positions>]

use oea_serve::backend::cpu::CpuBackend;
use oea_serve::config::ModelConfig;
use oea_serve::eval;
use oea_serve::model::ModelRunner;
use oea_serve::moe::policy::Policy;
use oea_serve::util::bench::Table;
use oea_serve::util::rng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let b: usize = args.first().map(|s| s.parse().unwrap()).unwrap_or(16);
    let positions: usize = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(12);

    let cfg = ModelConfig::preset(
        &std::env::var("OEA_BENCH_CONFIG").unwrap_or_else(|_| "smoke".into()),
    )?;
    let runner = ModelRunner::new(CpuBackend::synthetic(cfg.clone(), 0));
    let k = cfg.top_k;

    let mut rng = Rng::new(0);
    // mixed-domain batches: the diverse regime where piggybacking shines
    let seqs = eval::synthetic_sequences(&cfg, &mut rng, b, positions, true);

    println!("reference run (vanilla top-{k})...");
    let vanilla = eval::forced_run(&runner, &seqs, positions, Policy::Vanilla { k }, true)?;

    let mut table = Table::new(
        &format!("CE sweep @ B={b}, {positions} positions ({} config, cpu)", cfg.name),
        &["policy", "avg T", "CE delta", "KL vs vanilla", "moe us (cpu)"],
    );
    let mut arms: Vec<Policy> = Vec::new();
    for k0 in 2..k {
        arms.push(Policy::Pruned { k0, p: 1.0 });
    }
    for k0 in 1..k {
        arms.push(Policy::OeaSimplified { k0, k });
    }
    for pol in arms {
        let run = eval::forced_run(&runner, &seqs, positions, pol, true)?;
        let r = eval::ce_compare(&seqs, &run, &vanilla);
        table.row(vec![
            pol.label(),
            format!("{:.2}", r.avg_t),
            format!("{:+.4}", r.ce_delta),
            format!("{:.5}", r.kl_vanilla),
            format!("{:.0}", r.avg_moe_us),
        ]);
        println!("  done {}", pol.label());
    }
    table.row(vec![
        format!("vanilla(k={k})"),
        format!("{:.2}", vanilla.avg_t),
        "+0.0000".into(),
        "0.00000".into(),
        format!("{:.0}", vanilla.avg_moe_us),
    ]);
    table.print();
    println!(
        "\nReading: at equal avg T, OEA rows sit well below pruned rows on KL/CE\n\
         delta — Phase 2 recovers quality for free (paper Figs 2/3).\n"
    );
    Ok(())
}
