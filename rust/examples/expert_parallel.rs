//! Paper §7 "Extension to expert parallelism" — EXECUTED, not just
//! analyzed: this example boots the real serving engine on a CPU backend
//! whose packed expert panels are split into R per-rank shards
//! (`CpuOptions::ep_ranks`), routes with `Policy::Ep` (per-rank
//! piggybacking + underloaded-rank top-up, optionally composed with the
//! rank-local cache-aware residency boost), decodes a batch of requests
//! end to end, and reports the per-rank numbers that matter under EP:
//! max-rank activated experts (the latency driver), the max-rank
//! simulated step cost (`CostModel::step_us_ep`), per-rank load shares,
//! and — for the cached arm — per-rank page-in traffic.
//!
//!     cargo run --release --example expert_parallel

use oea_serve::backend::cpu::{CpuBackend, CpuOptions, DispatchMode};
use oea_serve::backend::Backend;
use oea_serve::config::ModelConfig;
use oea_serve::coordinator::{Engine, EngineConfig, GenRequest, Priority};
use oea_serve::eval;
use oea_serve::latency::H100Presets;
use oea_serve::model::ModelRunner;
use oea_serve::moe::ep::rank_of;
use oea_serve::moe::policy::Policy;
use oea_serve::residency::{EvictPolicy, ResidencyConfig};
use oea_serve::util::bench::Table;
use oea_serve::util::rng::Rng;
use oea_serve::util::stats::imbalance;

const B: usize = 16;
const RANKS: usize = 8;
const MAX_TOKENS: usize = 32;

struct Variant {
    name: &'static str,
    policy: Policy,
    residency: Option<ResidencyConfig>,
}

fn run_variant(cfg: &ModelConfig, v: &Variant) -> (f64, f64, f64, Vec<u64>, Vec<u64>) {
    let backend = CpuBackend::synthetic_with(
        cfg.clone(),
        0,
        CpuOptions {
            dispatch: DispatchMode::Grouped,
            threads: 0,
            residency: v.residency,
            ep_ranks: RANKS,
            ..CpuOptions::default()
        },
    );
    let runner = ModelRunner::new(backend);
    let mut engine = Engine::new(
        runner,
        EngineConfig {
            max_running: B,
            max_queue: usize::MAX,
            ..EngineConfig::new(v.policy, H100Presets::qwen3_235b_tp8())
        },
    )
    .unwrap();

    // one domain-pure prompt batch per request (the traffic shape the
    // router concentrates on, like the benches)
    let mut rng = Rng::new(7);
    for (i, prompt) in eval::synthetic_domain_prompts(cfg, &mut rng, 1, B, 12)
        .into_iter()
        .enumerate()
    {
        engine.submit(GenRequest {
            id: i as u64 + 1,
            prompt,
            max_new_tokens: MAX_TOKENS,
            temperature: 0.0,
            top_p: 1.0,
            seed: i as u64,
            policy: None,
            deadline_ms: None,
            priority: Priority::default(),
        })
        .unwrap();
    }
    engine.run_to_completion().unwrap();

    // per-rank routed-load shares from the backend's expert histogram
    let n = cfg.n_experts;
    let mut rank_load = vec![0u64; RANKS];
    for (e, &x) in engine.runner.backend.expert_loads().iter().enumerate() {
        rank_load[rank_of(e, n, RANKS)] += x;
    }
    // per-rank page-in bytes (the cached arm's balance story)
    let mut rank_paged = vec![0u64; RANKS];
    for l in 0..cfg.n_layers {
        if let Some(rcs) = engine.runner.backend.residency_rank_counters(l) {
            for (acc, c) in rank_paged.iter_mut().zip(rcs.iter()) {
                *acc += c.bytes_paged;
            }
        }
    }
    (
        engine.moe.avg_t(),
        engine.moe.avg_max_rank_t(),
        engine.moe.avg_latency_us(true),
        rank_load,
        rank_paged,
    )
}

fn main() {
    let cfg = ModelConfig::preset("small").unwrap();
    let (k, k0) = (cfg.top_k, (cfg.top_k / 2).max(1));
    let cache = ResidencyConfig::new(cfg.n_experts / 2, EvictPolicy::Lru, 0);
    let variants = [
        Variant { name: "vanilla top-k", policy: Policy::Vanilla { k }, residency: None },
        Variant {
            name: "EP-OEA topup=0",
            policy: Policy::Ep { k0, k, ranks: RANKS, topup: 0, alpha: 0.0 },
            residency: None,
        },
        Variant {
            name: "EP-OEA topup=2",
            policy: Policy::Ep { k0, k, ranks: RANKS, topup: 2, alpha: 0.0 },
            residency: None,
        },
        Variant {
            name: "EP-OEA + cache-aware",
            policy: Policy::Ep { k0, k, ranks: RANKS, topup: 0, alpha: 1.0 },
            residency: Some(cache),
        },
    ];

    let mut table = Table::new(
        &format!(
            "Executed expert parallelism ({} cfg, B={B}, {RANKS} ranks, \
             {MAX_TOKENS} tokens/request, engine end-to-end)",
            cfg.name
        ),
        &["policy", "avg T", "avg max-rank T", "sim step us (max-rank)", "load imbalance"],
    );
    let mut paged_rows = Vec::new();
    for v in &variants {
        let (avg_t, avg_mrt, sim_us, rank_load, rank_paged) = run_variant(&cfg, v);
        table.row(vec![
            v.name.to_string(),
            format!("{avg_t:.2}"),
            format!("{avg_mrt:.2}"),
            format!("{sim_us:.1}"),
            format!("{:.2}", imbalance(&rank_load)),
        ]);
        if v.residency.is_some() {
            paged_rows.push((v.name, rank_paged));
        }
    }
    table.print();

    for (name, paged) in paged_rows {
        let mb: Vec<String> =
            paged.iter().map(|&x| format!("{:.1}", x as f64 / 1e6)).collect();
        println!(
            "\n{name}: per-rank MB paged in = [{}]  (imbalance {:.2})",
            mb.join(", "),
            imbalance(&paged)
        );
    }
    println!(
        "\nEP step latency follows max-rank T (CostModel::step_us_ep). EP-OEA\n\
         lowers it roughly in proportion to the global T drop; top-up buys\n\
         back quality on underloaded ranks at nearly no max-rank cost; the\n\
         rank-local cache-aware boost steers each rank toward its own\n\
         resident panels, balancing page-in traffic across ranks.\n"
    );
}
