//! Paper §7 "Extension to expert parallelism": OEA with per-rank
//! piggybacking. Under EP, step latency follows the MAX per-rank activated
//! experts, so the goal shifts from minimizing T to balancing/minimizing
//! max_r T_r. This example drives the EP router over realistic
//! domain-structured score traces and reports max-rank-T and simulated
//! latency for vanilla / OEA / EP-OEA (with and without k0 top-up).
//!
//!     cargo run --release --example expert_parallel

use oea_serve::latency::CostModel;
use oea_serve::moe::ep::route_ep;
use oea_serve::moe::policy::{route, Policy, RoutingInput};
use oea_serve::moe::ScoreMatrix;
use oea_serve::util::bench::Table;
use oea_serve::util::rng::Rng;
use oea_serve::util::stats;

/// Domain-structured router scores: tokens cluster on domain-affine
/// experts, mirroring the trained router's behaviour (DESIGN.md §7).
fn trace_scores(rng: &mut Rng, b: usize, n: usize, n_domains: usize) -> ScoreMatrix {
    let mut centers = vec![0.0f64; n_domains * n];
    for x in centers.iter_mut() {
        *x = rng.gaussian();
    }
    let mut scores = vec![0.0f32; b * n];
    for i in 0..b {
        let d = rng.below(n_domains);
        let row = &mut scores[i * n..(i + 1) * n];
        let mut sum = 0.0f32;
        for (e, x) in row.iter_mut().enumerate() {
            let logit = 1.5 * centers[d * n + e] + rng.gaussian();
            *x = logit.exp() as f32;
            sum += *x;
        }
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
    ScoreMatrix::new(b, n, scores)
}

fn main() {
    let (b, n, k, k0, ranks) = (16usize, 128usize, 8usize, 3usize, 8usize);
    let steps = 400;
    let mut rng = Rng::new(0);
    // per-rank fetch cost: one rank's H100 slice (paper's TP/EP testbed)
    let cost = CostModel { fetch_us: 2.91, compute_us: 0.012, overhead_us: 33.5, page_in_us: 0.0 };

    let mut rows: Vec<(String, Vec<f64>, Vec<f64>)> = vec![
        ("vanilla top-8".into(), vec![], vec![]),
        (format!("OEA k0={k0} (global)"), vec![], vec![]),
        (format!("EP-OEA k0={k0}, topup=0"), vec![], vec![]),
        (format!("EP-OEA k0={k0}, topup=2"), vec![], vec![]),
    ];

    for _ in 0..steps {
        let s = trace_scores(&mut rng, b, n, 4);
        let live = vec![true; b];
        let input = RoutingInput { scores: &s, live: &live, mask_padding: true, resident: None };

        let per_rank = |active: &[u16]| {
            let mut c = vec![0usize; ranks];
            for &e in active {
                c[oea_serve::moe::ep::rank_of(e as usize, n, ranks)] += 1;
            }
            *c.iter().max().unwrap()
        };

        let v = route(Policy::Vanilla { k }, &input);
        rows[0].1.push(per_rank(&v.active) as f64);
        rows[0].2.push(v.t() as f64);

        let o = route(Policy::OeaSimplified { k0, k }, &input);
        rows[1].1.push(per_rank(&o.active) as f64);
        rows[1].2.push(o.t() as f64);

        let e0 = route_ep(&input, k0, k, ranks, 0);
        rows[2].1.push(e0.max_rank_t() as f64);
        rows[2].2.push(e0.inner.t() as f64);

        let e2 = route_ep(&input, k0, k, ranks, 2);
        rows[3].1.push(e2.max_rank_t() as f64);
        rows[3].2.push(e2.inner.t() as f64);
    }

    let mut table = Table::new(
        format!(
            "Expert-parallel OEA (paper §7): B={b}, N={n}, k={k}, {ranks} ranks, \
             {steps} simulated steps"
        )
        .as_str(),
        &["policy", "avg max-rank T", "avg total T", "sim step us (EP)"],
    );
    for (name, max_rank_t, total_t) in &rows {
        let mr = stats::mean(max_rank_t);
        table.row(vec![
            name.clone(),
            format!("{mr:.2}"),
            format!("{:.2}", stats::mean(total_t)),
            format!("{:.1}", cost.layer_us(mr.round() as usize, b * k / ranks, 0)),
        ]);
    }
    table.print();
    println!(
        "\nEP latency follows max-rank T: OEA lowers it roughly proportionally\n\
         to the global T drop, and the paper's suggested k0 top-up on\n\
         underloaded ranks buys extra quality at nearly no max-rank cost.\n"
    );
}
