//! Offline stub of the `xla` (xla-rs / PJRT) API surface used by
//! `oea-serve`'s `pjrt` feature.
//!
//! The real crate links against `xla_extension`, which cannot be vendored
//! here; this stub keeps the PJRT backend *compiling* on a clean machine
//! (CI runs `cargo check --features pjrt` against it) while every runtime
//! entry point fails fast with a clear error. To actually execute HLO
//! artifacts, point Cargo at the real implementation:
//!
//! ```toml
//! [patch."crates-io-or-path"]
//! # in the workspace root Cargo.toml:
//! # replace the `rust/xla-stub` path dependency with xla-rs + xla_extension
//! ```
//!
//! See the repository README ("PJRT backend") for the full recipe.

// the opaque `(())` fields exist only to forbid external construction
#![allow(dead_code)]

use std::fmt;
use std::path::Path;

/// Stub error carrying a human-readable message.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn stub<T>() -> Result<T, Error> {
    Err(Error(
        "xla stub: the `pjrt` feature was built against rust/xla-stub; \
         patch in the real xla-rs crate to execute HLO artifacts (see README)"
            .to_string(),
    ))
}

/// Element types the runtime moves across the host/device boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct Literal(());

/// Raw-bytes deserialization entry points (mirrors xla-rs).
pub trait FromRawBytes: Sized {
    type Context;
    fn read_npz<P: AsRef<Path>>(path: P, ctx: &Self::Context)
        -> Result<Vec<(String, Self)>, Error>;
}

impl FromRawBytes for Literal {
    type Context = ();
    fn read_npz<P: AsRef<Path>>(
        _path: P,
        _ctx: &Self::Context,
    ) -> Result<Vec<(String, Self)>, Error> {
        stub()
    }
}

impl Literal {
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal(())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        stub()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        stub()
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub()
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub()
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    /// Always errors in the stub: there is no PJRT runtime linked in.
    pub fn cpu() -> Result<PjRtClient, Error> {
        stub()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        stub()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer, Error> {
        stub()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        stub()
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        stub()
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must not pretend to work");
        assert!(err.to_string().contains("xla stub"));
    }
}
